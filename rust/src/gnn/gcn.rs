//! GCN with manual forward/backward on the hybrid kernels.
//!
//! Layer: `H_{l+1} = relu(Â · H_l · W_l)` (no relu on the output
//! layer). Â is symmetric, so the backward aggregation reuses the same
//! preprocessed SpMM plan: `dX = Â · dZ`.

use super::dense;
use super::{DenseBackend, Precision};
use crate::balance::BalanceParams;
use crate::dist::{DistParams, Op};
use crate::exec::{SpmmExecutor, TcBackend, Workspace};
use crate::planner::ReorderPolicy;
use crate::sparse::Dense;
use crate::util::SplitMix64;
use anyhow::Result;

/// A GCN model bound to one graph.
///
/// Per-epoch buffers are persistent: the layer caches, the backward
/// scratch, and the executor [`Workspace`] are sized by the first
/// forward/backward and reused for every following epoch. The
/// aggregation and backward paths allocate nothing per epoch; the only
/// recurring allocation is the small `N x classes` logits buffer each
/// forward moves out to its caller.
pub struct Gcn {
    pub weights: Vec<Dense>,
    pub spmm: SpmmExecutor,
    pub backend: DenseBackend,
    pub precision: Precision,
    /// per-layer inputs H_l; slot `n_layers` holds the logits
    cache_x: Vec<Dense>,
    /// per-layer aggregated Z_l = Â H_l
    cache_z: Vec<Dense>,
    /// backward gradient buffers (dY and dZ), reused across layers
    buf_dy: Dense,
    buf_dz: Dense,
    /// execution workspace shared by every `execute_into_with` call
    ws: Workspace,
}

/// Per-step forward output.
pub struct GcnForward {
    pub logits: Dense,
}

impl Gcn {
    /// Build a GCN with dims `[in, hidden, ..., classes]`.
    ///
    /// When `reorder` fires (see [`crate::reorder::decide`]), the
    /// aggregation plan is built on the row-clustered adjacency and
    /// the executor folds the inverse permutation back out at
    /// write-back, so layer activations stay in original node order —
    /// labels, masks, and features never need re-indexing.
    pub fn new(
        adj: &crate::sparse::Csr,
        dims: &[usize],
        dist: &DistParams,
        reorder: ReorderPolicy,
        tc_backend: TcBackend,
        backend: DenseBackend,
        precision: Precision,
        seed: u64,
    ) -> Self {
        assert!(dims.len() >= 2);
        let mut rng = SplitMix64::new(seed);
        let weights = dims
            .windows(2)
            .map(|d| Dense::glorot(&mut rng, d[0], d[1]))
            .collect();
        let bal = BalanceParams::default();
        let mode = crate::prep::PrepMode::Sequential;
        let plan = match crate::reorder::decide(reorder, adj, Op::Spmm, dist) {
            Some(perm) => crate::prep::preprocess_spmm_reordered(adj, dist, &bal, mode, &perm),
            None => crate::prep::preprocess_spmm(adj, dist, &bal, mode),
        };
        let spmm = SpmmExecutor::from_plan(plan, tc_backend);
        Self {
            weights,
            spmm,
            backend,
            precision,
            cache_x: Vec::new(),
            cache_z: Vec::new(),
            buf_dy: Dense::zeros(0, 0),
            buf_dz: Dense::zeros(0, 0),
            ws: Workspace::new(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Forward pass; caches intermediates for backward. Every buffer
    /// (layer caches, aggregation outputs, workspace) is reused across
    /// epochs — no `Dense::zeros` per forward.
    pub fn forward(&mut self, features: &Dense) -> Result<GcnForward> {
        let layers = self.n_layers();
        let last = layers - 1;
        if self.cache_x.len() != layers + 1 {
            self.cache_x = (0..layers + 1).map(|_| Dense::zeros(0, 0)).collect();
            self.cache_z = (0..layers).map(|_| Dense::zeros(0, 0)).collect();
        }
        self.cache_x[0].copy_from(features);
        round(self.precision, &mut self.cache_x[0]);
        for l in 0..layers {
            {
                // Z_l = Â H_l (aggregation on the hybrid kernels)
                let Gcn { spmm, cache_x, cache_z, ws, .. } = self;
                let x = &cache_x[l];
                let z = &mut cache_z[l];
                z.reshape_zeroed(spmm.dist.rows, x.cols);
                spmm.execute_into_with(x, z, ws)?;
            }
            round(self.precision, &mut self.cache_z[l]);
            {
                // H_{l+1} = relu(Z_l W_l) (no relu on the last layer)
                let Gcn { weights, backend, cache_x, cache_z, .. } = self;
                let (_, tail) = cache_x.split_at_mut(l + 1);
                dense::linear_into(backend, &cache_z[l], &weights[l], l != last, &mut tail[0])?;
            }
            round(self.precision, &mut self.cache_x[l + 1]);
        }
        // move the logits out instead of cloning: backward never reads
        // cache_x[layers] (relu masks stop at cache_x[layers - 1]) and
        // the next forward regrows the slot via linear_into
        let logits = std::mem::replace(&mut self.cache_x[layers], Dense::zeros(0, 0));
        Ok(GcnForward { logits })
    }

    /// Backward from dlogits; returns per-layer weight gradients.
    pub fn backward(&mut self, fwd: &GcnForward, dlogits: &Dense) -> Result<Vec<Dense>> {
        let last = self.n_layers() - 1;
        let mut grads: Vec<Dense> = Vec::with_capacity(self.n_layers());
        self.buf_dy.copy_from(dlogits);
        for l in (0..self.n_layers()).rev() {
            if l != last {
                // dX_{l+1} arrived in buf_dy; apply relu mask of
                // H_{l+1} (which is cache_x[l+1])
                dense::relu_bwd_inplace(&self.cache_x[l + 1], &mut self.buf_dy);
            }
            let mut dw = Dense::zeros(0, 0);
            dense::grad_w_into(&self.backend, &self.cache_z[l], &self.buf_dy, &mut dw)?;
            {
                let Gcn { weights, backend, buf_dy, buf_dz, .. } = self;
                dense::grad_x_into(backend, buf_dy, &weights[l], buf_dz)?;
            }
            {
                // dX_l = Âᵀ dZ = Â dZ (symmetric normalization)
                let Gcn { spmm, buf_dy, buf_dz, ws, .. } = self;
                buf_dy.reshape_zeroed(spmm.dist.rows, buf_dz.cols);
                spmm.execute_into_with(buf_dz, buf_dy, ws)?;
            }
            grads.push(dw);
        }
        grads.reverse();
        let _ = fwd;
        Ok(grads)
    }
}

/// Round a buffer to bf16 precision when the model asks for it.
fn round(precision: Precision, x: &mut Dense) {
    if precision == Precision::Bf16 {
        super::round_bf16_buf(&mut x.data);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::data::planted_partition;
    use crate::gnn::dense::softmax_xent;

    fn tiny_model(precision: Precision) -> (crate::gnn::GraphData, Gcn) {
        let data = planted_partition("t", 64, 4, 4.0, 0.8, 16, 7);
        let gcn = Gcn::new(
            &data.adj,
            &[16, 8, 4],
            &DistParams::default(),
            ReorderPolicy::Off,
            TcBackend::NativeBitmap,
            DenseBackend::Native,
            precision,
            42,
        );
        (data, gcn)
    }

    #[test]
    fn forward_shapes() {
        let (data, mut gcn) = tiny_model(Precision::F32);
        let fwd = gcn.forward(&data.features).unwrap();
        assert_eq!((fwd.logits.rows, fwd.logits.cols), (64, 4));
    }

    #[test]
    fn backward_gradient_check() {
        // numeric gradient check on a weight entry through the whole
        // network (spmm + linear + relu + xent)
        let (data, mut gcn) = tiny_model(Precision::F32);
        let mask = vec![true; 64];
        let fwd = gcn.forward(&data.features).unwrap();
        let (loss0, dlogits) = softmax_xent(&fwd.logits, &data.labels, &mask);
        let grads = gcn.backward(&fwd, &dlogits).unwrap();

        let eps = 3e-3f32;
        for (l, idx) in [(0usize, 5usize), (1usize, 3usize)] {
            let analytic = grads[l].data[idx];
            gcn.weights[l].data[idx] += eps;
            let fwd2 = gcn.forward(&data.features).unwrap();
            let (loss1, _) = softmax_xent(&fwd2.logits, &data.labels, &mask);
            gcn.weights[l].data[idx] -= eps;
            let numeric = ((loss1 - loss0) / eps as f64) as f32;
            assert!(
                (numeric - analytic).abs() < 0.05 * analytic.abs().max(0.05),
                "layer {l} idx {idx}: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn training_reduces_loss() {
        let (data, mut gcn) = tiny_model(Precision::F32);
        let mask = data.train_mask.clone();
        let mut losses = Vec::new();
        for _ in 0..30 {
            let fwd = gcn.forward(&data.features).unwrap();
            let (loss, dlogits) = softmax_xent(&fwd.logits, &data.labels, &mask);
            losses.push(loss);
            let grads = gcn.backward(&fwd, &dlogits).unwrap();
            for (w, g) in gcn.weights.iter_mut().zip(&grads) {
                for (wv, gv) in w.data.iter_mut().zip(&g.data) {
                    *wv -= 0.5 * gv;
                }
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.8),
            "loss did not drop: {:.4} -> {:.4}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn reordered_aggregation_matches_unreordered() {
        // adjacency whose rows were drawn from column clusters and
        // then shuffled: the Auto pre-metric fires, and the folded
        // output must match the unreordered model up to f32
        // reassociation (the permuted execution sums window
        // contributions in a different order)
        let mut rng = SplitMix64::new(77);
        let m = crate::sparse::gen::column_clustered(&mut rng, 256, 256, 4_000, 0.85, 8);
        let mut order: Vec<u32> = (0..m.rows as u32).collect();
        rng.shuffle(&mut order);
        let adj = crate::reorder::RowPerm::from_perm(order).apply_rows(&m);
        let feats = Dense::random(&mut rng, adj.cols, 16);
        let build = |rp: ReorderPolicy| {
            Gcn::new(
                &adj,
                &[16, 8, 4],
                &DistParams::default(),
                rp,
                TcBackend::NativeBitmap,
                DenseBackend::Native,
                Precision::F32,
                42,
            )
        };
        let mut plain = build(ReorderPolicy::Off);
        let mut reord = build(ReorderPolicy::Auto);
        assert!(plain.spmm.perm.is_none());
        assert!(reord.spmm.perm.is_some(), "Auto must fire on a shuffled clustered adjacency");
        let a = plain.forward(&feats).unwrap();
        let b = reord.forward(&feats).unwrap();
        let diff = a.logits.max_abs_diff(&b.logits);
        assert!(diff < 1e-3, "reordered logits diverged: {diff}");
    }

    #[test]
    fn bf16_forward_close_to_f32() {
        let (data, mut g32) = tiny_model(Precision::F32);
        let (_, mut g16) = tiny_model(Precision::Bf16);
        let f32out = g32.forward(&data.features).unwrap();
        let f16out = g16.forward(&data.features).unwrap();
        let diff = f32out.logits.max_abs_diff(&f16out.logits);
        assert!(diff > 0.0, "bf16 must differ");
        assert!(diff < 0.2, "bf16 too far: {diff}");
    }
}
