//! AGNN (attention-based GNN) with manual backward.
//!
//! Architecture (Thekumparampil et al., as in the paper's §5.5):
//! embedding `H_0 = relu(X W_0)`, then `L` propagation layers
//!
//!   e_ij  = β_l · cos(h_i, h_j)            (SDDMM on the edge pattern)
//!   α_i·  = softmax_row(e_i·)              (edge softmax)
//!   H_{l+1} = α · H_l                      (SpMM, values = α)
//!
//! and an output layer `logits = H_L W_1`.
//!
//! Runtime profile matches the paper's motivation: each propagation
//! layer is one SDDMM + one SpMM on the hybrid executors; the SpMM
//! plan is built once on the pattern and its values are refreshed
//! (`set_values`) every step.
//!
//! Backward: exact for W_0, W_1 and β_l; the hidden-state gradient
//! flows through the aggregation term (`dH += αᵀ dH'`, plus softmax →
//! β path). The `∂cos/∂H` term is dropped (standard practice in AGNN
//! reimplementations; documented in DESIGN.md §7) — convergence is
//! validated in the Fig-13 bench for GCN, AGNN is evaluated for
//! runtime (Fig 12) like the paper does.

use super::dense;
use super::DenseBackend;
use crate::balance::BalanceParams;
use crate::dist::DistParams;
use crate::exec::sddmm::SddmmExecutor;
use crate::exec::{SpmmExecutor, TcBackend};
use crate::sparse::{Csr, Dense};
use crate::util::SplitMix64;
use anyhow::Result;

/// AGNN model bound to one graph.
pub struct Agnn {
    pub w0: Dense,
    pub w1: Dense,
    pub betas: Vec<f32>,
    /// SpMM executor over the edge pattern (values refreshed per layer)
    pub spmm: SpmmExecutor,
    /// SpMM executor over the transposed pattern (for backward)
    pub spmm_t: SpmmExecutor,
    /// permutation: csr index -> transposed csr index
    t_perm: Vec<u32>,
    /// SDDMM executor over the pattern (cosine similarities)
    pub sddmm: SddmmExecutor,
    pub pattern: Csr,
    pub backend: DenseBackend,
    // forward caches
    cache: Vec<LayerCache>,
    cache_h0pre: Dense,
    cache_x: Dense,
}

struct LayerCache {
    h: Dense,
    /// α values (csr order)
    alpha: Vec<f32>,
    /// cos values (csr order)
    cos: Vec<f32>,
    /// normalized h rows (kept for the full-gradient extension)
    #[allow(dead_code)]
    hnorm: Dense,
}

impl Agnn {
    pub fn new(
        adj_raw: &Csr,
        feat_dim: usize,
        hidden: usize,
        classes: usize,
        n_prop: usize,
        dist: &DistParams,
        tc_backend: TcBackend,
        backend: DenseBackend,
        seed: u64,
    ) -> Self {
        let mut rng = SplitMix64::new(seed);
        // pattern with unit values (SDDMM scale = 1)
        let mut pattern = adj_raw.clone();
        for v in pattern.values.iter_mut() {
            *v = 1.0;
        }
        let spmm = SpmmExecutor::new(&pattern, dist, &BalanceParams::default(), tc_backend.clone());
        let pattern_t = pattern.transpose();
        let spmm_t = SpmmExecutor::new(&pattern_t, dist, &BalanceParams::default(), tc_backend.clone());
        // csr index -> index in transposed csr
        let t_perm = transpose_permutation(&pattern);
        let sddmm = SddmmExecutor::new(&pattern, &DistParams::sddmm_default(), tc_backend);
        Self {
            w0: Dense::glorot(&mut rng, feat_dim, hidden),
            w1: Dense::glorot(&mut rng, hidden, classes),
            betas: vec![1.0; n_prop],
            spmm,
            spmm_t,
            t_perm,
            sddmm,
            pattern,
            backend,
            cache: Vec::new(),
            cache_h0pre: Dense::zeros(0, 0),
            cache_x: Dense::zeros(0, 0),
        }
    }

    pub fn forward(&mut self, x: &Dense) -> Result<Dense> {
        self.cache.clear();
        self.cache_x = x.clone();
        let mut h = dense::linear(&self.backend, x, &self.w0, true)?;
        self.cache_h0pre = h.clone(); // post-relu h0 (relu mask source)
        for l in 0..self.betas.len() {
            let hnorm = normalize_rows(&h);
            // cos similarities on edges (hybrid SDDMM; pattern values = 1)
            let cos_csr = self.sddmm.execute(&hnorm, &hnorm)?;
            let cos = cos_csr.values;
            // e = β·cos, α = row softmax
            let alpha = row_softmax_scaled(&self.pattern, &cos, self.betas[l]);
            // H' = α H (hybrid SpMM with refreshed values)
            self.spmm.dist.set_values(&alpha);
            let h_next = self.spmm.execute(&h)?;
            self.cache.push(LayerCache { h: h.clone(), alpha, cos, hnorm });
            h = h_next;
        }
        dense::linear(&self.backend, &h, &self.w1, false)
    }

    /// Backward; returns (dW0, dW1, dbetas). Needs the final hidden
    /// state, so recomputes it cheaply from the last cache entry.
    pub fn backward(&mut self, dlogits: &Dense) -> Result<(Dense, Dense, Vec<f32>)> {
        // final hidden H_L = α_{L-1} H_{L-1}
        let h_last = if let Some(last) = self.cache.last() {
            self.spmm.dist.set_values(&last.alpha);
            self.spmm.execute(&last.h)?
        } else {
            self.cache_h0pre.clone()
        };
        let dw1 = dense::grad_w(&self.backend, &h_last, dlogits)?;
        let mut dh = dense::grad_x(&self.backend, dlogits, &self.w1)?;
        let mut dbetas = vec![0f32; self.betas.len()];

        for l in (0..self.betas.len()).rev() {
            let cache = &self.cache[l];
            // dα_ij = dH'_i · h_j  (SDDMM on the pattern)
            let dalpha_csr = self.sddmm.execute(&dh, &cache.h)?;
            let dalpha = dalpha_csr.values;
            // softmax backward: de_ij = α_ij (dα_ij - Σ_k α_ik dα_ik)
            let de = softmax_bwd(&self.pattern, &cache.alpha, &dalpha);
            // dβ = Σ de_ij cos_ij
            dbetas[l] = de.iter().zip(&cache.cos).map(|(d, c)| d * c).sum();
            // dH via the aggregation term: dH_prev = αᵀ dH'
            let alpha_t = permute(&cache.alpha, &self.t_perm);
            self.spmm_t.dist.set_values(&alpha_t);
            dh = self.spmm_t.execute(&dh)?;
            // (∂cos/∂H term dropped; see module docs)
        }
        // embed layer backward: H0 = relu(X W0)
        let dh0 = dense::relu_bwd(&self.cache_h0pre, &dh);
        let dw0 = dense::grad_w(&self.backend, &self.cache_x, &dh0)?;
        Ok((dw0, dw1, dbetas))
    }
}

/// Row-normalize (L2) a matrix.
fn normalize_rows(h: &Dense) -> Dense {
    let mut out = h.clone();
    for r in 0..h.rows {
        let row = out.row_mut(r);
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
        for v in row {
            *v /= norm;
        }
    }
    out
}

/// α = row-softmax of (β · cos) over the CSR pattern.
fn row_softmax_scaled(pattern: &Csr, cos: &[f32], beta: f32) -> Vec<f32> {
    let mut alpha = vec![0f32; cos.len()];
    for r in 0..pattern.rows {
        let (s, e) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        if s == e {
            continue;
        }
        let mut zmax = f32::MIN;
        for i in s..e {
            zmax = zmax.max(beta * cos[i]);
        }
        let mut sum = 0f32;
        for i in s..e {
            let v = (beta * cos[i] - zmax).exp();
            alpha[i] = v;
            sum += v;
        }
        for a in &mut alpha[s..e] {
            *a /= sum;
        }
    }
    alpha
}

/// Row-wise softmax backward over the CSR pattern.
fn softmax_bwd(pattern: &Csr, alpha: &[f32], dalpha: &[f32]) -> Vec<f32> {
    let mut de = vec![0f32; alpha.len()];
    for r in 0..pattern.rows {
        let (s, e) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        let dot: f32 = (s..e).map(|i| alpha[i] * dalpha[i]).sum();
        for i in s..e {
            de[i] = alpha[i] * (dalpha[i] - dot);
        }
    }
    de
}

/// For each csr position of `m`, its position in `m.transpose()`.
fn transpose_permutation(m: &Csr) -> Vec<u32> {
    let mut counts = vec![0u32; m.cols + 1];
    for &c in &m.col_idx {
        counts[c as usize + 1] += 1;
    }
    for i in 0..m.cols {
        counts[i + 1] += counts[i];
    }
    let mut cursor = counts;
    let mut perm = vec![0u32; m.nnz()];
    for r in 0..m.rows {
        let (s, e) = (m.row_ptr[r] as usize, m.row_ptr[r + 1] as usize);
        for i in s..e {
            let c = m.col_idx[i] as usize;
            perm[i] = cursor[c];
            cursor[c] += 1;
        }
    }
    perm
}

fn permute(vals: &[f32], perm: &[u32]) -> Vec<f32> {
    let mut out = vec![0f32; vals.len()];
    for (i, &p) in perm.iter().enumerate() {
        out[p as usize] = vals[i];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::data::planted_partition;
    use crate::gnn::dense::softmax_xent;

    fn tiny() -> (crate::gnn::GraphData, Agnn) {
        let data = planted_partition("t", 48, 4, 4.0, 0.8, 16, 9);
        let agnn = Agnn::new(
            &data.adj_raw,
            16,
            8,
            4,
            2,
            &DistParams::default(),
            TcBackend::NativeBitmap,
            DenseBackend::Native,
            11,
        );
        (data, agnn)
    }

    #[test]
    fn transpose_permutation_roundtrip() {
        let mut rng = SplitMix64::new(170);
        let m = crate::sparse::gen::uniform_random(&mut rng, 30, 30, 0.15);
        let perm = transpose_permutation(&m);
        let t = m.transpose();
        let permuted = permute(&m.values, &perm);
        assert_eq!(permuted, t.values);
    }

    #[test]
    fn alpha_rows_sum_to_one() {
        let (data, mut agnn) = tiny();
        agnn.forward(&data.features).unwrap();
        let alpha = &agnn.cache[0].alpha;
        for r in 0..data.adj_raw.rows {
            let (s, e) = (agnn.pattern.row_ptr[r] as usize, agnn.pattern.row_ptr[r + 1] as usize);
            if s == e {
                continue;
            }
            let sum: f32 = alpha[s..e].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} alpha sum {sum}");
        }
    }

    #[test]
    fn forward_shapes_and_cos_bounds() {
        let (data, mut agnn) = tiny();
        let logits = agnn.forward(&data.features).unwrap();
        assert_eq!((logits.rows, logits.cols), (48, 4));
        for &c in &agnn.cache[0].cos {
            assert!(c >= -1.0 - 1e-4 && c <= 1.0 + 1e-4, "cos {c}");
        }
    }

    #[test]
    fn training_reduces_loss() {
        let (data, mut agnn) = tiny();
        let mask = vec![true; 48];
        let mut losses = Vec::new();
        for _ in 0..25 {
            let logits = agnn.forward(&data.features).unwrap();
            let (loss, dlogits) = softmax_xent(&logits, &data.labels, &mask);
            losses.push(loss);
            let (dw0, dw1, dbetas) = agnn.backward(&dlogits).unwrap();
            for (w, g) in agnn.w0.data.iter_mut().zip(&dw0.data) {
                *w -= 0.3 * g;
            }
            for (w, g) in agnn.w1.data.iter_mut().zip(&dw1.data) {
                *w -= 0.3 * g;
            }
            for (b, g) in agnn.betas.iter_mut().zip(&dbetas) {
                *b -= 0.3 * g;
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.9),
            "loss did not drop: {:.4} -> {:.4}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn beta_gradient_check() {
        let (data, mut agnn) = tiny();
        let mask = vec![true; 48];
        let logits = agnn.forward(&data.features).unwrap();
        let (loss0, dlogits) = softmax_xent(&logits, &data.labels, &mask);
        let (_, _, dbetas) = agnn.backward(&dlogits).unwrap();
        let eps = 1e-2f32;
        agnn.betas[0] += eps;
        let logits1 = agnn.forward(&data.features).unwrap();
        let (loss1, _) = softmax_xent(&logits1, &data.labels, &mask);
        let numeric = ((loss1 - loss0) / eps as f64) as f32;
        // β gradient is exact up to the dropped ∂cos/∂H coupling (cos
        // does not depend on β, so this should be tight)
        assert!(
            (numeric - dbetas[0]).abs() < 0.1 * dbetas[0].abs().max(0.05),
            "numeric {numeric} vs analytic {}",
            dbetas[0]
        );
    }
}
