//! AGNN (attention-based GNN) with manual backward.
//!
//! Architecture (Thekumparampil et al., as in the paper's §5.5):
//! embedding `H_0 = relu(X W_0)`, then `L` propagation layers
//!
//!   e_ij  = β_l · cos(h_i, h_j)            (SDDMM on the edge pattern)
//!   α_i·  = softmax_row(e_i·)              (edge softmax)
//!   H_{l+1} = α · H_l                      (SpMM, values = α)
//!
//! and an output layer `logits = H_L W_1`.
//!
//! Runtime profile matches the paper's motivation: each propagation
//! layer is one SDDMM + one SpMM on the hybrid executors; the SpMM
//! plan is built once on the pattern and its values are refreshed
//! (`set_values`) every step. With [`Agnn::with_fused`] the three
//! per-layer stages collapse into one [`FusedAttention`] pass: scores
//! live in per-window workspace segments and the cos/α caches the
//! backward pass needs are *spilled* by the fused kernel (bit-identical
//! to the unfused chain's intermediates), so training sees the same
//! numbers through either forward.
//!
//! Backward: exact for W_0, W_1 and β_l; the hidden-state gradient
//! flows through the aggregation term (`dH += αᵀ dH'`, plus softmax →
//! β path). The `∂cos/∂H` term is dropped (standard practice in AGNN
//! reimplementations; documented in DESIGN.md §7) — convergence is
//! validated in the Fig-13 bench for GCN, AGNN is evaluated for
//! runtime (Fig 12) like the paper does.

use super::dense;
use super::DenseBackend;
use crate::balance::BalanceParams;
use crate::dist::DistParams;
use crate::exec::output::SharedOut;
use crate::exec::sddmm::SddmmExecutor;
use crate::exec::{FusedAttention, SpmmExecutor, TcBackend, Workspace};
use crate::prep::{AttentionPlan, SddmmPlan, SpmmPlan};
use crate::sparse::{Csr, Dense};
use crate::util::SplitMix64;
use anyhow::Result;
use std::sync::Arc;

/// AGNN model bound to one graph.
///
/// Like [`super::gcn::Gcn`], every per-step buffer is persistent: the
/// layer caches, the hidden-state ping-pong buffers, the edge-value
/// scratch vectors, and the executor [`Workspace`] are sized once and
/// reused across epochs; both SpMM plans and the SDDMM plan are built
/// once on the pattern and only value-refreshed (`set_values`).
pub struct Agnn {
    pub w0: Dense,
    pub w1: Dense,
    pub betas: Vec<f32>,
    /// SpMM executor over the edge pattern (values refreshed per layer)
    pub spmm: SpmmExecutor,
    /// SpMM executor over the transposed pattern (for backward)
    pub spmm_t: SpmmExecutor,
    /// permutation: csr index -> transposed csr index
    t_perm: Vec<u32>,
    /// SDDMM executor over the pattern (cosine similarities)
    pub sddmm: SddmmExecutor,
    /// One-pass SDDMM→softmax→SpMM executor over the same plans;
    /// `Some` after [`Agnn::with_fused`], and then the forward pass
    /// runs fused (backward is unchanged — it reads the spilled
    /// cos/α caches).
    fused: Option<FusedAttention>,
    /// Unit-valued edge pattern, `Arc`-shared with the SDDMM (and
    /// fused) executor — one CSR copy total, not one per consumer.
    pub pattern: Arc<Csr>,
    pub backend: DenseBackend,
    // forward caches
    cache: Vec<LayerCache>,
    cache_h0pre: Dense,
    cache_x: Dense,
    // persistent buffers (hidden-state ping-pong + backward scratch)
    buf_h: Dense,
    buf_tmp: Dense,
    buf_dh: Dense,
    buf_dalpha: Vec<f32>,
    buf_de: Vec<f32>,
    buf_alpha_t: Vec<f32>,
    /// execution workspace shared by every hybrid-kernel call
    ws: Workspace,
}

struct LayerCache {
    h: Dense,
    /// α values (csr order)
    alpha: Vec<f32>,
    /// cos values (csr order)
    cos: Vec<f32>,
    /// normalized h rows (kept for the full-gradient extension)
    #[allow(dead_code)]
    hnorm: Dense,
}

impl LayerCache {
    fn empty() -> Self {
        Self {
            h: Dense::zeros(0, 0),
            alpha: Vec::new(),
            cos: Vec::new(),
            hnorm: Dense::zeros(0, 0),
        }
    }
}

impl Agnn {
    pub fn new(
        adj_raw: &Csr,
        feat_dim: usize,
        hidden: usize,
        classes: usize,
        n_prop: usize,
        dist: &DistParams,
        tc_backend: TcBackend,
        backend: DenseBackend,
        seed: u64,
    ) -> Self {
        let mut rng = SplitMix64::new(seed);
        // pattern with unit values (SDDMM scale = 1), Arc-shared with
        // every executor that needs the CSR itself
        let mut pattern = adj_raw.clone();
        for v in pattern.values.iter_mut() {
            *v = 1.0;
        }
        let pattern = Arc::new(pattern);
        let spmm = SpmmExecutor::new(&pattern, dist, &BalanceParams::default(), tc_backend.clone());
        let pattern_t = pattern.transpose();
        let spmm_t =
            SpmmExecutor::new(&pattern_t, dist, &BalanceParams::default(), tc_backend.clone());
        // csr index -> index in transposed csr
        let t_perm = transpose_permutation(&pattern);
        let sddmm_dist = crate::dist::distribute_sddmm(&pattern, &DistParams::sddmm_default());
        let sddmm = SddmmExecutor::from_dist(sddmm_dist, Arc::clone(&pattern), tc_backend);
        Self {
            w0: Dense::glorot(&mut rng, feat_dim, hidden),
            w1: Dense::glorot(&mut rng, hidden, classes),
            betas: vec![1.0; n_prop],
            spmm,
            spmm_t,
            t_perm,
            sddmm,
            fused: None,
            pattern,
            backend,
            cache: Vec::new(),
            cache_h0pre: Dense::zeros(0, 0),
            cache_x: Dense::zeros(0, 0),
            buf_h: Dense::zeros(0, 0),
            buf_tmp: Dense::zeros(0, 0),
            buf_dh: Dense::zeros(0, 0),
            buf_dalpha: Vec::new(),
            buf_de: Vec::new(),
            buf_alpha_t: Vec::new(),
            ws: Workspace::new(),
        }
    }

    /// Switch the forward pass onto the one-pass fused
    /// SDDMM→softmax→SpMM executor. Reuses the plans the unfused
    /// executors already built (no re-preprocessing) and the
    /// `Arc`-shared pattern; backward is untouched because the fused
    /// kernel spills cos/α bit-identically to the unfused chain.
    pub fn with_fused(mut self) -> Result<Self> {
        let plan = AttentionPlan {
            sddmm: SddmmPlan {
                dist: self.sddmm.dist.clone(),
                sched: self.sddmm.sched.clone(),
                perm: None,
            },
            spmm: SpmmPlan {
                dist: self.spmm.dist.clone(),
                sched: self.spmm.sched.clone(),
                perm: None,
            },
        };
        let backend = self.sddmm.backend.clone();
        self.fused = Some(FusedAttention::from_plan(plan, Arc::clone(&self.pattern), backend)?);
        Ok(self)
    }

    /// Whether the forward pass runs on the fused executor.
    pub fn is_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Peak per-task score-segment size (elements) observed by the
    /// fused executor so far; 0 when unfused or before any forward.
    pub fn fused_peak_seg_elems(&self) -> usize {
        self.fused.as_ref().map_or(0, |f| f.peak_seg_elems())
    }

    pub fn forward(&mut self, x: &Dense) -> Result<Dense> {
        let n_prop = self.betas.len();
        if self.cache.len() != n_prop {
            self.cache = (0..n_prop).map(|_| LayerCache::empty()).collect();
        }
        self.cache_x.copy_from(x);
        dense::linear_into(&self.backend, x, &self.w0, true, &mut self.cache_h0pre)?;
        self.buf_h.copy_from(&self.cache_h0pre); // post-relu h0
        for l in 0..n_prop {
            {
                let Agnn { cache, buf_h, .. } = self;
                let c = &mut cache[l];
                c.h.copy_from(buf_h);
                c.hnorm.copy_from(buf_h);
                normalize_rows_inplace(&mut c.hnorm);
            }
            if self.fused.is_some() {
                // one fused pass per layer: scores stay in per-window
                // workspace segments; cos/α are spilled into the layer
                // cache for backward (bit-identical to the unfused
                // three-stage chain below).
                let Agnn { fused, cache, betas, buf_h, ws, .. } = self;
                let fx = fused.as_ref().unwrap();
                let c = &mut cache[l];
                let nnz = fx.pattern().nnz();
                c.cos.clear();
                c.cos.resize(nnz, 0.0);
                c.alpha.clear();
                c.alpha.resize(nnz, 0.0);
                let out = fx.execute_spill_with(
                    &c.hnorm,
                    &c.hnorm,
                    buf_h,
                    betas[l],
                    &mut c.cos,
                    &mut c.alpha,
                    ws,
                )?;
                *buf_h = out;
                continue;
            }
            {
                // cos similarities on edges (hybrid SDDMM; pattern
                // values = 1), straight into the cache's value buffer
                let Agnn { sddmm, cache, ws, .. } = self;
                let c = &mut cache[l];
                c.cos.clear();
                c.cos.resize(sddmm.pattern.nnz(), 0.0);
                let out = SharedOut::new(&mut c.cos);
                sddmm.execute_values_with(&c.hnorm, &c.hnorm, &out, ws)?;
            }
            {
                // e = β·cos, α = row softmax
                let Agnn { pattern, cache, betas, .. } = self;
                let c = &mut cache[l];
                row_softmax_scaled_into(pattern, &c.cos, betas[l], &mut c.alpha);
            }
            {
                // H' = α H (hybrid SpMM with refreshed values)
                let Agnn { spmm, cache, buf_h, buf_tmp, ws, .. } = self;
                spmm.dist.set_values(&cache[l].alpha);
                buf_tmp.reshape_zeroed(spmm.dist.rows, buf_h.cols);
                spmm.execute_into_with(buf_h, buf_tmp, ws)?;
                std::mem::swap(buf_h, buf_tmp);
            }
        }
        dense::linear(&self.backend, &self.buf_h, &self.w1, false)
    }

    /// Backward; returns (dW0, dW1, dbetas). Needs the final hidden
    /// state, so recomputes it cheaply from the last cache entry.
    pub fn backward(&mut self, dlogits: &Dense) -> Result<(Dense, Dense, Vec<f32>)> {
        {
            // final hidden H_L = α_{L-1} H_{L-1}, into buf_tmp
            let Agnn { spmm, cache, cache_h0pre, buf_tmp, ws, .. } = self;
            if let Some(last) = cache.last() {
                spmm.dist.set_values(&last.alpha);
                buf_tmp.reshape_zeroed(spmm.dist.rows, last.h.cols);
                spmm.execute_into_with(&last.h, buf_tmp, ws)?;
            } else {
                buf_tmp.copy_from(cache_h0pre);
            }
        }
        let dw1 = dense::grad_w(&self.backend, &self.buf_tmp, dlogits)?;
        {
            let Agnn { backend, w1, buf_dh, .. } = self;
            dense::grad_x_into(backend, dlogits, w1, buf_dh)?;
        }
        let mut dbetas = vec![0f32; self.betas.len()];

        for l in (0..self.betas.len()).rev() {
            {
                // dα_ij = dH'_i · h_j  (SDDMM on the pattern)
                let Agnn { sddmm, cache, buf_dh, buf_dalpha, ws, .. } = self;
                buf_dalpha.clear();
                buf_dalpha.resize(sddmm.pattern.nnz(), 0.0);
                let out = SharedOut::new(buf_dalpha);
                sddmm.execute_values_with(buf_dh, &cache[l].h, &out, ws)?;
            }
            {
                // softmax backward: de_ij = α_ij (dα_ij - Σ_k α_ik dα_ik)
                let Agnn { pattern, cache, buf_dalpha, buf_de, .. } = self;
                let c = &cache[l];
                softmax_bwd_into(pattern, &c.alpha, buf_dalpha, buf_de);
                // dβ = Σ de_ij cos_ij
                dbetas[l] = buf_de.iter().zip(&c.cos).map(|(d, cv)| d * cv).sum();
            }
            {
                // dH via the aggregation term: dH_prev = αᵀ dH'
                let Agnn { spmm_t, cache, t_perm, buf_alpha_t, buf_dh, buf_tmp, ws, .. } = self;
                permute_into(&cache[l].alpha, t_perm, buf_alpha_t);
                spmm_t.dist.set_values(buf_alpha_t);
                buf_tmp.reshape_zeroed(spmm_t.dist.rows, buf_dh.cols);
                spmm_t.execute_into_with(buf_dh, buf_tmp, ws)?;
                std::mem::swap(buf_dh, buf_tmp);
                // (∂cos/∂H term dropped; see module docs)
            }
        }
        // embed layer backward: H0 = relu(X W0)
        dense::relu_bwd_inplace(&self.cache_h0pre, &mut self.buf_dh);
        let dw0 = dense::grad_w(&self.backend, &self.cache_x, &self.buf_dh)?;
        Ok((dw0, dw1, dbetas))
    }
}

/// Row-normalize (L2) a matrix in place.
fn normalize_rows_inplace(h: &mut Dense) {
    for r in 0..h.rows {
        let row = h.row_mut(r);
        let norm: f32 = row.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
        for v in row {
            *v /= norm;
        }
    }
}

/// α = row-softmax of (β · cos) over the CSR pattern, into a reusable
/// buffer.
fn row_softmax_scaled_into(pattern: &Csr, cos: &[f32], beta: f32, alpha: &mut Vec<f32>) {
    alpha.clear();
    alpha.resize(cos.len(), 0.0);
    for r in 0..pattern.rows {
        let (s, e) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        if s == e {
            continue;
        }
        let mut zmax = f32::MIN;
        for i in s..e {
            zmax = zmax.max(beta * cos[i]);
        }
        let mut sum = 0f32;
        for i in s..e {
            let v = (beta * cos[i] - zmax).exp();
            alpha[i] = v;
            sum += v;
        }
        for a in &mut alpha[s..e] {
            *a /= sum;
        }
    }
}

/// Row-wise softmax backward over the CSR pattern, into a reusable
/// buffer.
fn softmax_bwd_into(pattern: &Csr, alpha: &[f32], dalpha: &[f32], de: &mut Vec<f32>) {
    de.clear();
    de.resize(alpha.len(), 0.0);
    for r in 0..pattern.rows {
        let (s, e) = (pattern.row_ptr[r] as usize, pattern.row_ptr[r + 1] as usize);
        let dot: f32 = (s..e).map(|i| alpha[i] * dalpha[i]).sum();
        for i in s..e {
            de[i] = alpha[i] * (dalpha[i] - dot);
        }
    }
}

/// For each csr position of `m`, its position in `m.transpose()`.
fn transpose_permutation(m: &Csr) -> Vec<u32> {
    let mut counts = vec![0u32; m.cols + 1];
    for &c in &m.col_idx {
        counts[c as usize + 1] += 1;
    }
    for i in 0..m.cols {
        counts[i + 1] += counts[i];
    }
    let mut cursor = counts;
    let mut perm = vec![0u32; m.nnz()];
    for r in 0..m.rows {
        let (s, e) = (m.row_ptr[r] as usize, m.row_ptr[r + 1] as usize);
        for i in s..e {
            let c = m.col_idx[i] as usize;
            perm[i] = cursor[c];
            cursor[c] += 1;
        }
    }
    perm
}

#[cfg(test)]
fn permute(vals: &[f32], perm: &[u32]) -> Vec<f32> {
    let mut out = Vec::new();
    permute_into(vals, perm, &mut out);
    out
}

/// Scatter `vals` through `perm` into a reusable buffer (every slot is
/// written — `perm` is a permutation — so no zeroing is needed).
fn permute_into(vals: &[f32], perm: &[u32], out: &mut Vec<f32>) {
    out.resize(vals.len(), 0.0);
    for (i, &p) in perm.iter().enumerate() {
        out[p as usize] = vals[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gnn::data::planted_partition;
    use crate::gnn::dense::softmax_xent;

    fn tiny() -> (crate::gnn::GraphData, Agnn) {
        let data = planted_partition("t", 48, 4, 4.0, 0.8, 16, 9);
        let agnn = Agnn::new(
            &data.adj_raw,
            16,
            8,
            4,
            2,
            &DistParams::default(),
            TcBackend::NativeBitmap,
            DenseBackend::Native,
            11,
        );
        (data, agnn)
    }

    #[test]
    fn transpose_permutation_roundtrip() {
        let mut rng = SplitMix64::new(170);
        let m = crate::sparse::gen::uniform_random(&mut rng, 30, 30, 0.15);
        let perm = transpose_permutation(&m);
        let t = m.transpose();
        let permuted = permute(&m.values, &perm);
        assert_eq!(permuted, t.values);
    }

    #[test]
    fn alpha_rows_sum_to_one() {
        let (data, mut agnn) = tiny();
        agnn.forward(&data.features).unwrap();
        let alpha = &agnn.cache[0].alpha;
        for r in 0..data.adj_raw.rows {
            let (s, e) = (agnn.pattern.row_ptr[r] as usize, agnn.pattern.row_ptr[r + 1] as usize);
            if s == e {
                continue;
            }
            let sum: f32 = alpha[s..e].iter().sum();
            assert!((sum - 1.0).abs() < 1e-4, "row {r} alpha sum {sum}");
        }
    }

    #[test]
    fn forward_shapes_and_cos_bounds() {
        let (data, mut agnn) = tiny();
        let logits = agnn.forward(&data.features).unwrap();
        assert_eq!((logits.rows, logits.cols), (48, 4));
        for &c in &agnn.cache[0].cos {
            assert!(c >= -1.0 - 1e-4 && c <= 1.0 + 1e-4, "cos {c}");
        }
    }

    #[test]
    fn training_reduces_loss() {
        let (data, mut agnn) = tiny();
        let mask = vec![true; 48];
        let mut losses = Vec::new();
        for _ in 0..25 {
            let logits = agnn.forward(&data.features).unwrap();
            let (loss, dlogits) = softmax_xent(&logits, &data.labels, &mask);
            losses.push(loss);
            let (dw0, dw1, dbetas) = agnn.backward(&dlogits).unwrap();
            for (w, g) in agnn.w0.data.iter_mut().zip(&dw0.data) {
                *w -= 0.3 * g;
            }
            for (w, g) in agnn.w1.data.iter_mut().zip(&dw1.data) {
                *w -= 0.3 * g;
            }
            for (b, g) in agnn.betas.iter_mut().zip(&dbetas) {
                *b -= 0.3 * g;
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.9),
            "loss did not drop: {:.4} -> {:.4}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn fused_forward_matches_unfused() {
        let (data, mut plain) = tiny();
        let (_, fused) = tiny();
        let mut fused = fused.with_fused().unwrap();
        assert!(fused.is_fused() && !plain.is_fused());
        let want = plain.forward(&data.features).unwrap();
        let got = fused.forward(&data.features).unwrap();
        // layer 0 sees bit-identical inputs, so the spilled cos/α must
        // match the unfused chain exactly (backward depends on them)
        assert_eq!(plain.cache[0].cos, fused.cache[0].cos, "layer 0 cos");
        assert_eq!(plain.cache[0].alpha, fused.cache[0].alpha, "layer 0 alpha");
        // deeper layers and logits tolerate TC-stage reassociation in
        // the fused SpMM half
        for l in 1..plain.cache.len() {
            for (a, b) in plain.cache[l].alpha.iter().zip(&fused.cache[l].alpha) {
                assert!((a - b).abs() <= 1e-4 * (1.0 + b.abs()), "layer {l} alpha: {a} vs {b}");
            }
        }
        for (a, b) in want.data.iter().zip(&got.data) {
            assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "logits: {a} vs {b}");
        }
        // the fused pass bounded its intermediate by one window's
        // nonzeros — never the edge count
        let peak = fused.fused_peak_seg_elems();
        let bound = fused.fused.as_ref().unwrap().max_window_nnz();
        assert!(peak > 0 && peak <= bound, "peak {peak} outside (0, {bound}]");
        assert_eq!(plain.fused_peak_seg_elems(), 0);
    }

    #[test]
    fn fused_training_reduces_loss() {
        // backward runs unchanged off the spilled cos/α caches
        let (data, agnn) = tiny();
        let mut agnn = agnn.with_fused().unwrap();
        let mask = vec![true; 48];
        let mut losses = Vec::new();
        for _ in 0..25 {
            let logits = agnn.forward(&data.features).unwrap();
            let (loss, dlogits) = softmax_xent(&logits, &data.labels, &mask);
            losses.push(loss);
            let (dw0, dw1, dbetas) = agnn.backward(&dlogits).unwrap();
            for (w, g) in agnn.w0.data.iter_mut().zip(&dw0.data) {
                *w -= 0.3 * g;
            }
            for (w, g) in agnn.w1.data.iter_mut().zip(&dw1.data) {
                *w -= 0.3 * g;
            }
            for (b, g) in agnn.betas.iter_mut().zip(&dbetas) {
                *b -= 0.3 * g;
            }
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.9),
            "fused loss did not drop: {:.4} -> {:.4}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn beta_gradient_check() {
        let (data, mut agnn) = tiny();
        let mask = vec![true; 48];
        let logits = agnn.forward(&data.features).unwrap();
        let (loss0, dlogits) = softmax_xent(&logits, &data.labels, &mask);
        let (_, _, dbetas) = agnn.backward(&dlogits).unwrap();
        let eps = 1e-2f32;
        agnn.betas[0] += eps;
        let logits1 = agnn.forward(&data.features).unwrap();
        let (loss1, _) = softmax_xent(&logits1, &data.labels, &mask);
        let numeric = ((loss1 - loss0) / eps as f64) as f32;
        // β gradient is exact up to the dropped ∂cos/∂H coupling (cos
        // does not depend on β, so this should be tight)
        assert!(
            (numeric - dbetas[0]).abs() < 0.1 * dbetas[0].abs().max(0.05),
            "numeric {numeric} vs analytic {}",
            dbetas[0]
        );
    }
}
