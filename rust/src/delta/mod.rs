//! Incremental plan maintenance for evolving sparsity patterns.
//!
//! Every layer below serving assumes a frozen pattern, so one edge
//! insertion used to force full re-fingerprint + re-distribution +
//! re-balancing — a cold `PlanCache` miss. But Libra's distribution is
//! strictly *window-local* ([`crate::dist`]): window `w`'s θ-split
//! depends only on rows `8w..8w+8`, and so do the balance decisions.
//! An edge-batch delta therefore invalidates exactly the windows whose
//! rows it touches; everything else can be spliced from the old plan
//! with index shifts.
//!
//! The layer-by-layer contract (each step is bit-identical to running
//! the full pipeline on the post-delta matrix, enforced by the
//! differential tests in `tests/delta_differential.rs`):
//!
//! * [`Csr::apply_delta`] rebuilds only the touched row spans and
//!   bulk-copies the untouched runs;
//! * [`crate::sparse::PatternDigests::update`] re-hashes only touched
//!   windows, recombining to exactly `fingerprint(new_m)`;
//! * [`patch_spmm_dist`] / [`patch_sddmm_dist`] re-run the window
//!   distributor only for touched windows and splice maximal untouched
//!   window runs as bulk array copies (one constant CSR-index shift
//!   per run, because a run's elements all move by the same amount);
//! * [`patch_spmm_schedule`] / [`patch_sddmm_schedule`] re-run the
//!   window balance kernel only for touched windows and copy the rest
//!   of the segments with block/element shifts;
//! * [`SpmmPlan::apply_delta`] / [`SddmmPlan::apply_delta`] compose the
//!   two, and `serve::PlanCache::apply_delta` turns a mutated pattern
//!   into a patched cache entry instead of a cold miss.
//!
//! A delta never changes the matrix shape — evolving-graph workloads
//! mutate edges, not the vertex set (grow the vertex set by building a
//! new matrix).

use crate::balance::{
    sddmm_window_kernel, spmm_win_block_start, spmm_window_kernel, BalanceParams, FlexTile,
    SddmmSchedule, SpmmSchedule, TcSegment,
};
use crate::dist::spmm::distribute_window;
use crate::dist::{distribute_sddmm, DistParams, DistStats, SddmmDist, SpmmDist};
use crate::format::{TcBlocks, WINDOW};
use crate::prep::{row_slice, SddmmPlan, SpmmPlan};
use crate::sparse::Csr;

/// One edit of an [`EdgeDelta`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DeltaOp {
    /// Insert the edge with this value, or overwrite the value if the
    /// edge already exists (a value-only upsert still invalidates the
    /// window: patched distributions reuse untouched windows' *values*).
    Upsert(f32),
    /// Remove the edge (which must exist in the base matrix).
    Delete,
}

/// A batch of edge edits against a fixed base pattern.
///
/// The batch is a *set of final states*, not a sequence: each `(row,
/// col)` coordinate ends up inserted-or-updated (`Upsert`) or removed
/// (`Delete`), and when the same coordinate is pushed twice the last
/// push wins ([`EdgeDelta::canonical`]). Deltas are validated against
/// the base matrix by [`Csr::apply_delta`]: out-of-range coordinates
/// and deletions of absent edges are errors, not no-ops — a serving
/// tenant mutating a graph it mis-tracks should hear about it.
#[derive(Debug, Clone, Default)]
pub struct EdgeDelta {
    ops: Vec<(u32, u32, DeltaOp)>,
}

impl EdgeDelta {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert `(row, col)` with value `v`, or overwrite its value.
    pub fn upsert(&mut self, row: usize, col: usize, v: f32) -> &mut Self {
        self.ops.push((row as u32, col as u32, DeltaOp::Upsert(v)));
        self
    }

    /// Delete `(row, col)` (must exist in the base matrix).
    pub fn delete(&mut self, row: usize, col: usize) -> &mut Self {
        self.ops.push((row as u32, col as u32, DeltaOp::Delete));
        self
    }

    /// Number of (possibly duplicate) edits pushed.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// The raw edit list, in push order.
    pub fn ops(&self) -> &[(u32, u32, DeltaOp)] {
        &self.ops
    }

    /// Edits sorted by `(row, col)` with duplicates collapsed to the
    /// last-pushed op per coordinate — the form every patcher consumes.
    pub fn canonical(&self) -> Vec<(u32, u32, DeltaOp)> {
        let mut sorted = self.ops.clone();
        // stable by construction: ties keep push order, so the later
        // push survives the dedup below
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut canon: Vec<(u32, u32, DeltaOp)> = Vec::with_capacity(sorted.len());
        for op in sorted {
            match canon.last_mut() {
                Some(last) if last.0 == op.0 && last.1 == op.1 => *last = op,
                _ => canon.push(op),
            }
        }
        canon
    }

    /// Sorted, deduplicated indices of the row windows this delta
    /// touches. Value-only upserts count: the distribution patchers
    /// reuse untouched windows' value arrays verbatim.
    pub fn touched_windows(&self) -> Vec<usize> {
        let mut ws: Vec<usize> = self.ops.iter().map(|&(r, _, _)| r as usize / WINDOW).collect();
        ws.sort_unstable();
        ws.dedup();
        ws
    }
}

impl Csr {
    /// Apply an edge-batch delta, rebuilding only the touched row spans
    /// (untouched row runs are bulk copies). Errors on out-of-range
    /// coordinates and on deleting an absent edge; the matrix shape is
    /// preserved. Equivalent to rebuilding the matrix from scratch
    /// with the edits applied.
    pub fn apply_delta(&self, delta: &EdgeDelta) -> anyhow::Result<Csr> {
        let ops = delta.canonical();
        for &(r, c, op) in &ops {
            anyhow::ensure!(
                (r as usize) < self.rows,
                "delta row {r} out of range (matrix has {} rows)",
                self.rows
            );
            anyhow::ensure!(
                (c as usize) < self.cols,
                "delta col {c} out of range (matrix has {} cols)",
                self.cols
            );
            if matches!(op, DeltaOp::Delete) {
                anyhow::ensure!(
                    self.get(r as usize, c as usize).is_some(),
                    "delta deletes absent edge ({r}, {c})"
                );
            }
        }

        let mut row_ptr = vec![0u32; self.rows + 1];
        let mut col_idx: Vec<u32> = Vec::with_capacity(self.col_idx.len() + ops.len());
        let mut values: Vec<f32> = Vec::with_capacity(self.values.len() + ops.len());
        let mut oi = 0usize;
        let mut r = 0usize;
        while r < self.rows {
            let edit_row = if oi < ops.len() { ops[oi].0 as usize } else { self.rows };
            if r < edit_row {
                // bulk-copy the untouched run [r, edit_row)
                let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[edit_row] as usize);
                let base = col_idx.len() as i64 - s as i64;
                col_idx.extend_from_slice(&self.col_idx[s..e]);
                values.extend_from_slice(&self.values[s..e]);
                for rr in r..edit_row {
                    row_ptr[rr + 1] = (self.row_ptr[rr + 1] as i64 + base) as u32;
                }
                r = edit_row;
                continue;
            }
            // merge row r's old elements with its ops (both col-sorted)
            let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            let mut oj = oi;
            while oj < ops.len() && ops[oj].0 as usize == r {
                oj += 1;
            }
            let (mut i, mut j) = (s, oi);
            while i < e && j < oj {
                let (oc, nc) = (self.col_idx[i], ops[j].1);
                if oc < nc {
                    col_idx.push(oc);
                    values.push(self.values[i]);
                    i += 1;
                } else if nc < oc {
                    // absent coordinate: validated above to be an upsert
                    if let DeltaOp::Upsert(v) = ops[j].2 {
                        col_idx.push(nc);
                        values.push(v);
                    }
                    j += 1;
                } else {
                    match ops[j].2 {
                        DeltaOp::Upsert(v) => {
                            col_idx.push(oc);
                            values.push(v);
                        }
                        DeltaOp::Delete => {}
                    }
                    i += 1;
                    j += 1;
                }
            }
            while i < e {
                col_idx.push(self.col_idx[i]);
                values.push(self.values[i]);
                i += 1;
            }
            while j < oj {
                if let DeltaOp::Upsert(v) = ops[j].2 {
                    col_idx.push(ops[j].1);
                    values.push(v);
                }
                j += 1;
            }
            row_ptr[r + 1] = col_idx.len() as u32;
            oi = oj;
            r += 1;
        }
        Ok(Csr { rows: self.rows, cols: self.cols, row_ptr, col_idx, values })
    }
}

/// Patch an SpMM distribution after a delta: re-distribute exactly the
/// `touched` windows (sorted, as from [`EdgeDelta::touched_windows`])
/// from `new_m`, and splice every maximal untouched window run from
/// `old` as bulk array copies. Within an untouched run all CSR source
/// indices shift by one constant (`new_m.row_ptr[lo] - old_m.row_ptr[lo]`
/// at the run start), which is what makes the splice a copy rather
/// than a recomputation. Bit-identical to `distribute_spmm(new_m,
/// params)` provided `old` was built from `old_m` with the same
/// `params`.
pub fn patch_spmm_dist(
    old: &SpmmDist,
    old_m: &Csr,
    new_m: &Csr,
    touched: &[usize],
    params: &DistParams,
) -> SpmmDist {
    assert_eq!(old.rows, new_m.rows, "deltas never change the shape");
    assert_eq!(old.cols, new_m.cols, "deltas never change the shape");
    let rows = old.rows;
    let n_windows = rows.div_ceil(WINDOW);
    let old_wbs = spmm_win_block_start(old);
    let k = old.tc.k;

    let mut tc = TcBlocks::new(k);
    let mut tc_src_idx: Vec<u32> = Vec::with_capacity(old.tc_src_idx.len());
    let mut flex_row_ptr = vec![0u32; rows + 1];
    let mut flex_cols: Vec<u32> = Vec::with_capacity(old.flex_cols.len());
    let mut flex_vals: Vec<f32> = Vec::with_capacity(old.flex_vals.len());
    let mut flex_src_idx: Vec<u32> = Vec::with_capacity(old.flex_src_idx.len());

    let mut ti = 0usize;
    let mut w = 0usize;
    while w < n_windows {
        while ti < touched.len() && touched[ti] < w {
            ti += 1;
        }
        if ti < touched.len() && touched[ti] == w {
            // touched: re-run the window distributor on the new matrix
            let o = distribute_window(new_m, w, params);
            let lo = w * WINDOW;
            let mut acc = *tc.val_ptr.last().unwrap();
            for &bm in &o.bitmaps {
                tc.window_of.push(w as u32);
                tc.bitmaps.push(bm);
                acc += bm.count_ones();
                tc.val_ptr.push(acc);
            }
            tc.cols.extend_from_slice(&o.block_cols);
            tc.values.extend_from_slice(&o.values);
            tc_src_idx.extend_from_slice(&o.tc_src_idx);
            let mut facc = flex_vals.len() as u32;
            for (i, &len) in o.flex_row_len.iter().enumerate() {
                facc += len;
                flex_row_ptr[lo + i + 1] = facc;
            }
            flex_cols.extend_from_slice(&o.flex_cols);
            flex_vals.extend_from_slice(&o.flex_vals);
            flex_src_idx.extend_from_slice(&o.flex_src_idx);
            w += 1;
        } else {
            // untouched run [w, wr): splice with shifted indices
            let wr = if ti < touched.len() { touched[ti].min(n_windows) } else { n_windows };
            let lo = w * WINDOW;
            let hi_run = (wr * WINDOW).min(rows);
            let (bs, be) = (old_wbs[w] as usize, old_wbs[wr] as usize);
            let (vs, ve) = (old.tc.val_ptr[bs] as usize, old.tc.val_ptr[be] as usize);
            let shift = new_m.row_ptr[lo] as i64 - old_m.row_ptr[lo] as i64;
            let vdiff = *tc.val_ptr.last().unwrap() as i64 - old.tc.val_ptr[bs] as i64;
            tc.window_of.extend_from_slice(&old.tc.window_of[bs..be]);
            tc.cols.extend_from_slice(&old.tc.cols[bs * k..be * k]);
            tc.bitmaps.extend_from_slice(&old.tc.bitmaps[bs..be]);
            tc.values.extend_from_slice(&old.tc.values[vs..ve]);
            let vp = old.tc.val_ptr[bs + 1..=be].iter().map(|&p| (p as i64 + vdiff) as u32);
            tc.val_ptr.extend(vp);
            tc_src_idx.extend(old.tc_src_idx[vs..ve].iter().map(|&p| (p as i64 + shift) as u32));
            let (fs, fe) = (old.flex_row_ptr[lo] as usize, old.flex_row_ptr[hi_run] as usize);
            let fbase = flex_vals.len() as u32;
            for r in lo..hi_run {
                flex_row_ptr[r + 1] = fbase + old.flex_row_ptr[r + 1] - fs as u32;
            }
            flex_cols.extend_from_slice(&old.flex_cols[fs..fe]);
            flex_vals.extend_from_slice(&old.flex_vals[fs..fe]);
            let fsi = old.flex_src_idx[fs..fe].iter().map(|&p| (p as i64 + shift) as u32);
            flex_src_idx.extend(fsi);
            w = wr;
        }
    }
    let nnz_tc = tc.nnz();
    let stats = DistStats {
        nnz_total: new_m.nnz(),
        nnz_tc,
        nnz_flex: flex_vals.len(),
        n_blocks: tc.n_blocks(),
        n_windows,
        padding_ratio: tc.padding_ratio(),
    };
    SpmmDist {
        rows,
        cols: old.cols,
        tc,
        tc_src_idx,
        flex_row_ptr,
        flex_cols,
        flex_vals,
        flex_src_idx,
        stats,
    }
}

/// Patch an SDDMM distribution after a delta — the [`patch_spmm_dist`]
/// mirror. Touched windows re-run the distributor on a row slice of
/// `new_m` (re-globalized exactly as the parallel preprocessing path
/// does); untouched window runs are spliced with a constant CSR-index
/// shift per run. Bit-identical to `distribute_sddmm(new_m, params)`.
pub fn patch_sddmm_dist(
    old: &SddmmDist,
    old_m: &Csr,
    new_m: &Csr,
    touched: &[usize],
    params: &DistParams,
) -> SddmmDist {
    assert_eq!(old.rows, new_m.rows, "deltas never change the shape");
    assert_eq!(old.cols, new_m.cols, "deltas never change the shape");
    let rows = old.rows;
    let n_windows = rows.div_ceil(WINDOW);
    let k = old.tc.k;
    let mut out = SddmmDist { rows, cols: old.cols, tc: TcBlocks::new(k), ..Default::default() };

    let mut ti = 0usize;
    let mut w = 0usize;
    while w < n_windows {
        while ti < touched.len() && touched[ti] < w {
            ti += 1;
        }
        if ti < touched.len() && touched[ti] == w {
            let lo = w * WINDOW;
            let hi = ((w + 1) * WINDOW).min(rows);
            let sub = row_slice(new_m, lo, hi);
            let d = distribute_sddmm(&sub, params);
            let val_base = out.tc.values.len() as u32;
            let pos_base = new_m.row_ptr[lo];
            for _ in 0..d.tc.n_blocks() {
                out.tc.window_of.push(w as u32);
            }
            out.tc.cols.extend_from_slice(&d.tc.cols);
            out.tc.bitmaps.extend_from_slice(&d.tc.bitmaps);
            out.tc.values.extend_from_slice(&d.tc.values);
            out.tc.val_ptr.extend(d.tc.val_ptr[1..].iter().map(|&p| p + val_base));
            out.tc_out_idx.extend(d.tc_out_idx.iter().map(|&p| p + pos_base));
            out.flex_rows.extend(d.flex_rows.iter().map(|&r| r + lo as u32));
            out.flex_cols.extend_from_slice(&d.flex_cols);
            out.flex_vals.extend_from_slice(&d.flex_vals);
            out.flex_out_idx.extend(d.flex_out_idx.iter().map(|&p| p + pos_base));
            w += 1;
        } else {
            let wr = if ti < touched.len() { touched[ti].min(n_windows) } else { n_windows };
            let lo = w * WINDOW;
            let hi_run = (wr * WINDOW).min(rows);
            let bs = old.tc.window_of.partition_point(|&x| (x as usize) < w);
            let be = old.tc.window_of.partition_point(|&x| (x as usize) < wr);
            let (vs, ve) = (old.tc.val_ptr[bs] as usize, old.tc.val_ptr[be] as usize);
            let fs = old.flex_rows.partition_point(|&r| (r as usize) < lo);
            let fe = old.flex_rows.partition_point(|&r| (r as usize) < hi_run);
            let shift = new_m.row_ptr[lo] as i64 - old_m.row_ptr[lo] as i64;
            let vdiff = out.tc.values.len() as i64 - old.tc.val_ptr[bs] as i64;
            out.tc.window_of.extend_from_slice(&old.tc.window_of[bs..be]);
            out.tc.cols.extend_from_slice(&old.tc.cols[bs * k..be * k]);
            out.tc.bitmaps.extend_from_slice(&old.tc.bitmaps[bs..be]);
            out.tc.values.extend_from_slice(&old.tc.values[vs..ve]);
            let vp = old.tc.val_ptr[bs + 1..=be].iter().map(|&p| (p as i64 + vdiff) as u32);
            out.tc.val_ptr.extend(vp);
            let oi = old.tc_out_idx[vs..ve].iter().map(|&p| (p as i64 + shift) as u32);
            out.tc_out_idx.extend(oi);
            out.flex_rows.extend_from_slice(&old.flex_rows[fs..fe]);
            out.flex_cols.extend_from_slice(&old.flex_cols[fs..fe]);
            out.flex_vals.extend_from_slice(&old.flex_vals[fs..fe]);
            let foi = old.flex_out_idx[fs..fe].iter().map(|&p| (p as i64 + shift) as u32);
            out.flex_out_idx.extend(foi);
            w = wr;
        }
    }
    let nnz_tc = out.tc.nnz();
    out.stats = DistStats {
        nnz_total: new_m.nnz(),
        nnz_tc,
        nnz_flex: new_m.nnz() - nnz_tc,
        n_blocks: out.tc.n_blocks(),
        n_windows,
        padding_ratio: out.tc.padding_ratio(),
    };
    out
}

/// Patch an SpMM balance schedule after its distribution was patched:
/// re-run the window balance kernel only for `touched` windows (on
/// `new_dist`) and copy every other window's segments with block /
/// element index shifts. Bit-identical to `balance_spmm(new_dist,
/// params)` provided `old_sched` came from `balance_spmm(old_dist,
/// params)`.
pub fn patch_spmm_schedule(
    old_sched: &SpmmSchedule,
    old_dist: &SpmmDist,
    new_dist: &SpmmDist,
    touched: &[usize],
    params: &BalanceParams,
) -> SpmmSchedule {
    let rows = new_dist.rows;
    let n_windows = rows.div_ceil(WINDOW);
    let old_wbs = spmm_win_block_start(old_dist);
    let new_wbs = spmm_win_block_start(new_dist);
    let mut sched = SpmmSchedule::default();
    let (mut tc_i, mut long_i, mut short_i) = (0usize, 0usize, 0usize);
    let mut ti = 0usize;
    for w in 0..n_windows {
        let lo = w * WINDOW;
        let hi = ((w + 1) * WINDOW).min(rows);
        // the old schedule's slice for this window (segments are
        // window-ascending, tiles row-ascending)
        let mut tc_j = tc_i;
        while tc_j < old_sched.tc_segments.len()
            && old_sched.tc_segments[tc_j].window as usize == w
        {
            tc_j += 1;
        }
        let mut long_j = long_i;
        while long_j < old_sched.long_tiles.len()
            && (old_sched.long_tiles[long_j].row as usize) < hi
        {
            long_j += 1;
        }
        let mut short_j = short_i;
        while short_j < old_sched.short_tiles.len()
            && (old_sched.short_tiles[short_j].row as usize) < hi
        {
            short_j += 1;
        }
        while ti < touched.len() && touched[ti] < w {
            ti += 1;
        }
        if ti < touched.len() && touched[ti] == w {
            spmm_window_kernel(
                new_dist,
                w,
                new_wbs[w] as usize,
                new_wbs[w + 1] as usize,
                params,
                &mut sched,
            );
        } else {
            let block_shift = new_wbs[w] as i64 - old_wbs[w] as i64;
            let elem_shift = new_dist.flex_row_ptr[lo] as i64 - old_dist.flex_row_ptr[lo] as i64;
            // Every segment of an untouched window carries the window's
            // atomic flag (a long tile's extra `row_split` trigger
            // implies the window-level `long_decomposed` trigger), so
            // the per-window count can be reconstructed from the copies.
            let mut window_atomic = false;
            for seg in &old_sched.tc_segments[tc_i..tc_j] {
                window_atomic |= seg.atomic;
                sched.tc_segments.push(TcSegment {
                    block_start: (seg.block_start as i64 + block_shift) as u32,
                    block_end: (seg.block_end as i64 + block_shift) as u32,
                    ..*seg
                });
            }
            for t in &old_sched.long_tiles[long_i..long_j] {
                window_atomic |= t.atomic;
                sched.long_tiles.push(FlexTile {
                    elem_start: (t.elem_start as i64 + elem_shift) as u32,
                    elem_end: (t.elem_end as i64 + elem_shift) as u32,
                    ..*t
                });
            }
            for t in &old_sched.short_tiles[short_i..short_j] {
                window_atomic |= t.atomic;
                sched.short_tiles.push(FlexTile {
                    elem_start: (t.elem_start as i64 + elem_shift) as u32,
                    elem_end: (t.elem_end as i64 + elem_shift) as u32,
                    ..*t
                });
            }
            if window_atomic {
                sched.atomic_windows += 1;
            }
        }
        tc_i = tc_j;
        long_i = long_j;
        short_i = short_j;
    }
    sched
}

/// Patch an SDDMM balance schedule — the [`patch_spmm_schedule`]
/// mirror (no atomic-window accounting: SDDMM segments are never
/// atomic). Bit-identical to `balance_sddmm(new_dist, params)`.
pub fn patch_sddmm_schedule(
    old_sched: &SddmmSchedule,
    old_dist: &SddmmDist,
    new_dist: &SddmmDist,
    touched: &[usize],
    params: &BalanceParams,
) -> SddmmSchedule {
    let rows = new_dist.rows;
    let n_windows = rows.div_ceil(WINDOW);
    let mut sched = SddmmSchedule::default();
    let (mut tc_i, mut long_i, mut short_i) = (0usize, 0usize, 0usize);
    // running block / flex-element cursors into both distributions
    let (mut old_b, mut new_b) = (0usize, 0usize);
    let (mut old_f, mut new_f) = (0usize, 0usize);
    let mut ti = 0usize;
    for w in 0..n_windows {
        let hi = ((w + 1) * WINDOW).min(rows);
        let mut old_be = old_b;
        while old_be < old_dist.tc.n_blocks() && old_dist.tc.window_of[old_be] as usize == w {
            old_be += 1;
        }
        let mut new_be = new_b;
        while new_be < new_dist.tc.n_blocks() && new_dist.tc.window_of[new_be] as usize == w {
            new_be += 1;
        }
        let mut old_fe = old_f;
        while old_fe < old_dist.flex_rows.len() && (old_dist.flex_rows[old_fe] as usize) < hi {
            old_fe += 1;
        }
        let mut new_fe = new_f;
        while new_fe < new_dist.flex_rows.len() && (new_dist.flex_rows[new_fe] as usize) < hi {
            new_fe += 1;
        }
        let mut tc_j = tc_i;
        while tc_j < old_sched.tc_segments.len()
            && old_sched.tc_segments[tc_j].window as usize == w
        {
            tc_j += 1;
        }
        let mut long_j = long_i;
        while long_j < old_sched.long_tiles.len()
            && (old_sched.long_tiles[long_j].row as usize) < hi
        {
            long_j += 1;
        }
        let mut short_j = short_i;
        while short_j < old_sched.short_tiles.len()
            && (old_sched.short_tiles[short_j].row as usize) < hi
        {
            short_j += 1;
        }
        while ti < touched.len() && touched[ti] < w {
            ti += 1;
        }
        if ti < touched.len() && touched[ti] == w {
            sddmm_window_kernel(
                new_dist,
                w as u32,
                new_b,
                new_be,
                new_f,
                new_fe,
                params,
                &mut sched,
            );
        } else {
            let block_shift = new_b as i64 - old_b as i64;
            let elem_shift = new_f as i64 - old_f as i64;
            for seg in &old_sched.tc_segments[tc_i..tc_j] {
                sched.tc_segments.push(TcSegment {
                    block_start: (seg.block_start as i64 + block_shift) as u32,
                    block_end: (seg.block_end as i64 + block_shift) as u32,
                    ..*seg
                });
            }
            for t in &old_sched.long_tiles[long_i..long_j] {
                sched.long_tiles.push(FlexTile {
                    elem_start: (t.elem_start as i64 + elem_shift) as u32,
                    elem_end: (t.elem_end as i64 + elem_shift) as u32,
                    ..*t
                });
            }
            for t in &old_sched.short_tiles[short_i..short_j] {
                sched.short_tiles.push(FlexTile {
                    elem_start: (t.elem_start as i64 + elem_shift) as u32,
                    elem_end: (t.elem_end as i64 + elem_shift) as u32,
                    ..*t
                });
            }
        }
        tc_i = tc_j;
        long_i = long_j;
        short_i = short_j;
        old_b = old_be;
        new_b = new_be;
        old_f = old_fe;
        new_f = new_fe;
    }
    sched
}

impl SpmmPlan {
    /// Patch this plan to the post-delta matrix `new_m`, recomputing
    /// only the `touched` windows' distribution and balance decisions
    /// (see module docs). `old_m` is the matrix this plan was built
    /// from; `dist_params`/`balance_params` must match the plan's.
    /// Bit-identical to `preprocess_spmm(new_m, ...)`.
    ///
    /// Only unpermuted plans can be patched: a reordered plan's
    /// windows do not align with the edit batch's row windows, so the
    /// serving layer rebuilds those instead (`PlanCache::apply_delta`
    /// refuses them before this is reached).
    pub fn apply_delta(
        &self,
        old_m: &Csr,
        new_m: &Csr,
        touched: &[usize],
        dist_params: &DistParams,
        balance_params: &BalanceParams,
    ) -> SpmmPlan {
        assert!(self.perm.is_none(), "cannot patch a reordered plan");
        let dist = patch_spmm_dist(&self.dist, old_m, new_m, touched, dist_params);
        let sched = patch_spmm_schedule(&self.sched, &self.dist, &dist, touched, balance_params);
        SpmmPlan { dist, sched, perm: None }
    }
}

impl SddmmPlan {
    /// Patch this plan to the post-delta matrix `new_m` — the
    /// [`SpmmPlan::apply_delta`] mirror.
    pub fn apply_delta(
        &self,
        old_m: &Csr,
        new_m: &Csr,
        touched: &[usize],
        dist_params: &DistParams,
        balance_params: &BalanceParams,
    ) -> SddmmPlan {
        assert!(self.perm.is_none(), "cannot patch a reordered plan");
        let dist = patch_sddmm_dist(&self.dist, old_m, new_m, touched, dist_params);
        let sched = patch_sddmm_schedule(&self.sched, &self.dist, &dist, touched, balance_params);
        SddmmPlan { dist, sched, perm: None }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Coo};
    use crate::util::SplitMix64;

    #[test]
    fn canonical_is_sorted_and_last_wins() {
        let mut d = EdgeDelta::new();
        d.upsert(3, 4, 1.0).delete(1, 2).upsert(3, 4, 9.0).upsert(0, 0, 5.0).delete(3, 4);
        let c = d.canonical();
        assert_eq!(c.len(), 3);
        assert_eq!((c[0].0, c[0].1), (0, 0));
        assert_eq!((c[1].0, c[1].1), (1, 2));
        assert_eq!((c[2].0, c[2].1), (3, 4));
        // (3, 4): pushed upsert, upsert, delete — the delete wins
        assert!(matches!(c[2].2, DeltaOp::Delete));
        assert_eq!(d.len(), 5);
        assert!(!d.is_empty());
    }

    #[test]
    fn touched_windows_includes_value_only_upserts() {
        let mut d = EdgeDelta::new();
        d.upsert(0, 1, 2.0); // window 0
        d.upsert(17, 3, 4.0); // window 2
        d.upsert(18, 5, 6.0); // window 2 again
        assert_eq!(d.touched_windows(), vec![0, 2]);
    }

    #[test]
    fn apply_delta_matches_rebuilt_coo() {
        let mut rng = SplitMix64::new(500);
        let m = gen::uniform_random(&mut rng, 40, 30, 0.1);
        let mut d = EdgeDelta::new();
        // delete the first edge, upsert a new one and revalue another
        let (r0, c0) = (0usize, m.col_idx[m.row_ptr[0] as usize] as usize);
        let first_row_nonempty = m.row_ptr[1] > m.row_ptr[0];
        if first_row_nonempty {
            d.delete(r0, c0);
        }
        d.upsert(39, 29, 7.5);
        let new_m = m.apply_delta(&d).unwrap();
        new_m.validate().unwrap();
        // rebuild from scratch via COO for comparison
        let mut coo = Coo::new(40, 30);
        for r in 0..m.rows {
            let (cols, vals) = m.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                if first_row_nonempty && r == r0 && c as usize == c0 {
                    continue;
                }
                if r == 39 && c == 29 {
                    continue;
                }
                coo.push(r, c as usize, v);
            }
        }
        coo.push(39, 29, 7.5);
        let want = coo.to_csr();
        assert_eq!(new_m.row_ptr, want.row_ptr);
        assert_eq!(new_m.col_idx, want.col_idx);
        assert_eq!(new_m.values, want.values);
    }

    #[test]
    fn apply_delta_value_only_upsert_keeps_pattern() {
        let mut rng = SplitMix64::new(501);
        let m = gen::uniform_random(&mut rng, 20, 20, 0.2);
        let pos = m.nnz() / 2;
        let r = m.row_ptr.partition_point(|&p| p as usize <= pos) - 1;
        let c = m.col_idx[pos] as usize;
        let mut d = EdgeDelta::new();
        d.upsert(r, c, 42.0);
        let new_m = m.apply_delta(&d).unwrap();
        assert_eq!(new_m.row_ptr, m.row_ptr);
        assert_eq!(new_m.col_idx, m.col_idx);
        assert_eq!(new_m.get(r, c), Some(42.0));
    }

    #[test]
    fn apply_delta_rejects_bad_ops() {
        let m = gen::uniform_random(&mut SplitMix64::new(502), 10, 10, 0.1);
        let mut d = EdgeDelta::new();
        d.upsert(10, 0, 1.0);
        assert!(m.apply_delta(&d).is_err());
        let mut d = EdgeDelta::new();
        d.upsert(0, 10, 1.0);
        assert!(m.apply_delta(&d).is_err());
        // deleting an absent edge is an error, not a no-op
        let mut d = EdgeDelta::new();
        let absent_col = (0..10).find(|&c| m.get(0, c).is_none()).unwrap();
        d.delete(0, absent_col);
        assert!(m.apply_delta(&d).is_err());
    }

    #[test]
    fn empty_delta_is_identity() {
        let m = gen::uniform_random(&mut SplitMix64::new(503), 25, 25, 0.15);
        let new_m = m.apply_delta(&EdgeDelta::new()).unwrap();
        assert_eq!(new_m.row_ptr, m.row_ptr);
        assert_eq!(new_m.col_idx, m.col_idx);
        assert_eq!(new_m.values, m.values);
    }

    #[test]
    fn patched_dist_matches_scratch_on_small_case() {
        let mut rng = SplitMix64::new(504);
        let m = gen::uniform_random(&mut rng, 64, 48, 0.1);
        let params = DistParams::default();
        let old = crate::dist::distribute_spmm(&m, &params);
        let mut d = EdgeDelta::new();
        d.upsert(20, 7, 3.0).delete(5, m.col_idx[m.row_ptr[5] as usize] as usize);
        let new_m = m.apply_delta(&d).unwrap();
        let patched = patch_spmm_dist(&old, &m, &new_m, &d.touched_windows(), &params);
        let scratch = crate::dist::distribute_spmm(&new_m, &params);
        assert_eq!(patched.tc.bitmaps, scratch.tc.bitmaps);
        assert_eq!(patched.tc.cols, scratch.tc.cols);
        assert_eq!(patched.tc.values, scratch.tc.values);
        assert_eq!(patched.tc.val_ptr, scratch.tc.val_ptr);
        assert_eq!(patched.tc.window_of, scratch.tc.window_of);
        assert_eq!(patched.tc_src_idx, scratch.tc_src_idx);
        assert_eq!(patched.flex_row_ptr, scratch.flex_row_ptr);
        assert_eq!(patched.flex_cols, scratch.flex_cols);
        assert_eq!(patched.flex_vals, scratch.flex_vals);
        assert_eq!(patched.flex_src_idx, scratch.flex_src_idx);
        assert_eq!(patched.stats, scratch.stats);
        patched.validate_cover(&new_m).unwrap();
    }

    #[test]
    fn patched_plan_matches_scratch_on_small_case() {
        let mut rng = SplitMix64::new(505);
        let m = gen::power_law(&mut rng, 96, 6.0, 2.0);
        let dp = DistParams::default();
        let bp = BalanceParams::default();
        let plan = crate::prep::preprocess_spmm(&m, &dp, &bp, crate::prep::PrepMode::Sequential);
        let mut d = EdgeDelta::new();
        d.upsert(90, 3, 1.0).upsert(0, 2, 2.0);
        let new_m = m.apply_delta(&d).unwrap();
        let patched = plan.apply_delta(&m, &new_m, &d.touched_windows(), &dp, &bp);
        let scratch =
            crate::prep::preprocess_spmm(&new_m, &dp, &bp, crate::prep::PrepMode::Sequential);
        assert_eq!(patched.sched.tc_segments, scratch.sched.tc_segments);
        assert_eq!(patched.sched.long_tiles, scratch.sched.long_tiles);
        assert_eq!(patched.sched.short_tiles, scratch.sched.short_tiles);
        assert_eq!(patched.sched.atomic_windows, scratch.sched.atomic_windows);
        assert_eq!(patched.dist.flex_row_ptr, scratch.dist.flex_row_ptr);
        assert_eq!(patched.dist.tc.bitmaps, scratch.dist.tc.bitmaps);
    }

    #[test]
    fn patched_sddmm_plan_matches_scratch_on_small_case() {
        let mut rng = SplitMix64::new(506);
        let m = gen::uniform_random(&mut rng, 80, 40, 0.12);
        let dp = DistParams::sddmm_default();
        let bp = BalanceParams::default();
        let plan = crate::prep::preprocess_sddmm(&m, &dp, &bp, crate::prep::PrepMode::Sequential);
        let mut d = EdgeDelta::new();
        d.upsert(40, 10, 4.0).upsert(41, 11, 5.0);
        let new_m = m.apply_delta(&d).unwrap();
        let patched = plan.apply_delta(&m, &new_m, &d.touched_windows(), &dp, &bp);
        let scratch =
            crate::prep::preprocess_sddmm(&new_m, &dp, &bp, crate::prep::PrepMode::Sequential);
        assert_eq!(patched.dist.tc.bitmaps, scratch.dist.tc.bitmaps);
        assert_eq!(patched.dist.tc.val_ptr, scratch.dist.tc.val_ptr);
        assert_eq!(patched.dist.tc_out_idx, scratch.dist.tc_out_idx);
        assert_eq!(patched.dist.flex_rows, scratch.dist.flex_rows);
        assert_eq!(patched.dist.flex_out_idx, scratch.dist.flex_out_idx);
        assert_eq!(patched.sched.tc_segments, scratch.sched.tc_segments);
        assert_eq!(patched.sched.long_tiles, scratch.sched.long_tiles);
        assert_eq!(patched.sched.short_tiles, scratch.sched.short_tiles);
        patched.dist.validate_cover(&new_m).unwrap();
    }
}
