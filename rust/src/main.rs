//! Libra CLI: preprocess, run, and inspect hybrid sparse operators.
//!
//! Subcommands:
//!   spmm   --matrix <.mtx|gen:SPEC> [--n 128] [--theta N|auto] [--backend native|pjrt]
//!   sddmm  --matrix <.mtx|gen:SPEC> [--k 32]  [--theta N|auto] [--backend native|pjrt]
//!   stats  --matrix <.mtx|gen:SPEC>            sparsity profile + distribution preview
//!   tune   [--n 128] [--k 32]                  print tuned thresholds per profile
//!   gnn    [--model gcn|agnn] [--epochs 50]    train on a synthetic citation graph
//!
//! `gen:SPEC` synthesizes a matrix, e.g. `gen:powerlaw:4096:12` or
//! `gen:banded:2048:6`, `gen:uniform:4096:0.001`, `gen:blockdiag:2048:24`.

use anyhow::{bail, Context, Result};
use libra::balance::BalanceParams;
use libra::costmodel::{self, HardwareProfile};
use libra::dist::{DistParams, Op};
use libra::exec::sddmm::SddmmExecutor;
use libra::exec::{SpmmExecutor, TcBackend};
use libra::sparse::{gen, mm_io, Csr, Dense};
use libra::util::SplitMix64;
use std::collections::HashMap;

fn main() -> Result<()> {
    libra::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "spmm" => cmd_spmm(&flags),
        "sddmm" => cmd_sddmm(&flags),
        "stats" => cmd_stats(&flags),
        "tune" => cmd_tune(&flags),
        "gnn" => cmd_gnn(&flags),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn print_usage() {
    println!(
        "libra — heterogeneous sparse matrix multiplication\n\n\
         usage: libra <spmm|sddmm|stats|tune|gnn> [flags]\n\
         \x20 spmm   --matrix <path.mtx|gen:SPEC> [--n 128] [--theta auto] [--backend native]\n\
         \x20 sddmm  --matrix <path.mtx|gen:SPEC> [--k 32]  [--theta auto] [--backend native]\n\
         \x20 stats  --matrix <path.mtx|gen:SPEC>\n\
         \x20 tune   [--n 128] [--k 32]\n\
         \x20 gnn    [--model gcn] [--epochs 50]\n\
         gen:SPEC: gen:powerlaw:N:DEG | gen:banded:N:BAND | gen:uniform:N:DENSITY | gen:blockdiag:N:BLOCKS"
    );
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
            match val {
                Some(v) => {
                    map.insert(key.to_string(), v.clone());
                    i += 2;
                }
                None => {
                    map.insert(key.to_string(), "true".into());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    map
}

fn load_matrix(flags: &HashMap<String, String>) -> Result<Csr> {
    let spec = flags.get("matrix").context("--matrix required")?;
    if let Some(genspec) = spec.strip_prefix("gen:") {
        let parts: Vec<&str> = genspec.split(':').collect();
        let mut rng = SplitMix64::new(
            flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42),
        );
        let n: usize = parts.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
        Ok(match parts[0] {
            "powerlaw" => {
                let deg: f64 = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(12.0);
                gen::power_law(&mut rng, n, deg, 2.0)
            }
            "banded" => {
                let band: usize = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
                gen::banded(&mut rng, n, band, 0.6)
            }
            "uniform" => {
                let d: f64 = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(1e-3);
                gen::uniform_random(&mut rng, n, n, d)
            }
            "blockdiag" => {
                let blocks: usize = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
                gen::block_diag_noise(&mut rng, n, blocks, 0.4, 1e-3)
            }
            other => bail!("unknown generator '{other}'"),
        })
    } else {
        mm_io::read_mtx_file(spec)
    }
}

fn backend(flags: &HashMap<String, String>) -> Result<TcBackend> {
    match flags.get("backend").map(String::as_str).unwrap_or("native") {
        "native" => Ok(TcBackend::NativeBitmap),
        "pjrt" => {
            let rt = libra::runtime::Runtime::open_default()
                .context("opening artifacts (run `make artifacts`)")?;
            Ok(TcBackend::Pjrt(std::sync::Arc::new(rt)))
        }
        other => bail!("unknown backend '{other}'"),
    }
}

fn theta(flags: &HashMap<String, String>, op: Op, n: usize) -> DistParams {
    match flags.get("theta").map(String::as_str) {
        None | Some("auto") => costmodel::substrate_params(op, n),
        Some(v) => DistParams { threshold: v.parse().unwrap_or(3), fill_padding: true },
    }
}

fn cmd_spmm(flags: &HashMap<String, String>) -> Result<()> {
    let m = load_matrix(flags)?;
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(128);
    let params = theta(flags, Op::Spmm, n);
    let exec = SpmmExecutor::new(&m, &params, &BalanceParams::default(), backend(flags)?);
    println!(
        "matrix {}x{} nnz={} | theta={} -> {} blocks ({:.1}% padding), {} flex nnz",
        m.rows,
        m.cols,
        m.nnz(),
        params.threshold,
        exec.dist.stats.n_blocks,
        exec.dist.stats.padding_ratio * 100.0,
        exec.dist.stats.nnz_flex
    );
    let mut rng = SplitMix64::new(1);
    let b = Dense::random(&mut rng, m.cols, n);
    exec.execute(&b)?; // warm
    let t = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        std::hint::black_box(exec.execute(&b)?);
    }
    let secs = t.elapsed().as_secs_f64() / reps as f64;
    println!(
        "spmm N={n}: {:.3} ms, {:.2} GFLOPS, {} pjrt calls",
        secs * 1e3,
        2.0 * m.nnz() as f64 * n as f64 / secs / 1e9,
        exec.counters.snapshot().pjrt_calls
    );
    Ok(())
}

fn cmd_sddmm(flags: &HashMap<String, String>) -> Result<()> {
    let m = load_matrix(flags)?;
    let k: usize = flags.get("k").and_then(|s| s.parse().ok()).unwrap_or(32);
    let params = theta(flags, Op::Sddmm, k);
    let exec = SddmmExecutor::new(&m, &params, backend(flags)?);
    let mut rng = SplitMix64::new(2);
    let a = Dense::random(&mut rng, m.rows, k);
    let b = Dense::random(&mut rng, m.cols, k);
    exec.execute(&a, &b)?;
    let t = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        std::hint::black_box(exec.execute(&a, &b)?);
    }
    let secs = t.elapsed().as_secs_f64() / reps as f64;
    println!(
        "sddmm K={k}: theta={} | {:.3} ms, {:.2} GFLOPS ({:.1}% nnz structured)",
        params.threshold,
        secs * 1e3,
        2.0 * m.nnz() as f64 * k as f64 / secs / 1e9,
        exec.dist.stats.tc_fraction() * 100.0
    );
    Ok(())
}

fn cmd_stats(flags: &HashMap<String, String>) -> Result<()> {
    let m = load_matrix(flags)?;
    let p = libra::sparse::stats::profile(&m);
    println!("rows={} cols={} nnz={}", p.rows, p.cols, p.nnz);
    println!("avg row len {:.2} (max {}, std {:.2})", p.avg_row_len, p.max_row_len, p.row_len_std);
    println!("nonzero 8x1 vectors: {} (mean nnz {:.2})", p.n_vectors, p.mean_vec_nnz);
    println!("NNZ-1 vector ratio: {:.3}", p.nnz1_ratio);
    let region = if p.nnz1_ratio > 0.75 {
        "flexible-engine advantage"
    } else if p.nnz1_ratio < 0.25 {
        "structured-engine advantage"
    } else {
        "hybrid advantage"
    };
    println!("Fig-1 region: {region}");
    for th in [1usize, 2, 3, 4, 8] {
        let d = libra::dist::distribute_spmm(&m, &DistParams { threshold: th, fill_padding: true });
        println!(
            "  theta={th}: {:.1}% structured, {} blocks, {:.1}% padding",
            d.stats.tc_fraction() * 100.0,
            d.stats.n_blocks,
            d.stats.padding_ratio * 100.0
        );
    }
    Ok(())
}

fn cmd_tune(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(128);
    let k: usize = flags.get("k").and_then(|s| s.parse().ok()).unwrap_or(32);
    for hw in [HardwareProfile::h100(), HardwareProfile::cpu_substrate()] {
        println!(
            "{:>14}: peak ratio {:>5.1}x  theta_spmm(N={n}) = {}  theta_sddmm(K={k}) = {}",
            hw.name,
            hw.peak_ratio(),
            costmodel::analytic_threshold(&hw, Op::Spmm, n),
            costmodel::analytic_threshold(&hw, Op::Sddmm, k),
        );
    }
    Ok(())
}

fn cmd_gnn(flags: &HashMap<String, String>) -> Result<()> {
    use libra::gnn::data::planted_partition;
    use libra::gnn::trainer::{train_agnn, train_gcn, TrainConfig};
    use libra::gnn::DenseBackend;
    let model = flags.get("model").map(String::as_str).unwrap_or("gcn");
    let epochs: usize = flags.get("epochs").and_then(|s| s.parse().ok()).unwrap_or(50);
    let data = planted_partition("cora_syn", 2708, 7, 6.0, 0.85, 128, 17);
    let cfg = TrainConfig { epochs, lr: 0.01, hidden: 64, layers: 5, ..Default::default() };
    let params = costmodel::substrate_params(Op::Spmm, cfg.hidden);
    let stats = match model {
        "gcn" => train_gcn(&data, &cfg, &params, TcBackend::NativeBitmap, DenseBackend::Native)?,
        "agnn" => train_agnn(&data, &cfg, &params, TcBackend::NativeBitmap, DenseBackend::Native)?,
        other => bail!("unknown model '{other}'"),
    };
    println!(
        "{model}: {} epochs, final acc {:.3}, {:.1} ms/epoch, prep {:.2}%",
        epochs,
        stats.final_accuracy,
        stats.total_train_time() / epochs as f64 * 1e3,
        stats.prep_fraction() * 100.0
    );
    Ok(())
}
