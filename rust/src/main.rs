//! Libra CLI: preprocess, run, serve, and inspect hybrid sparse operators.
//!
//! Subcommands:
//!   spmm   --matrix <.mtx|gen:SPEC> [--n 128] [--theta auto|auto-refined|N] [--precision f32|bf16|f16] [--reorder off|auto]
//!   sddmm  --matrix <.mtx|gen:SPEC> [--k 32]  [--theta auto|auto-refined|N] [--precision f32|bf16|f16] [--reorder off|auto] [--reduce sum|max|mean]
//!   stats  --matrix <.mtx|gen:SPEC>            sparsity profile + distribution preview
//!   tune   [--matrix SPEC] [--n 128] [--k 32]  resolve θ through the serving Planner path
//!   gnn    [--model gcn|agnn] [--epochs 50] [--fused]  train on a synthetic citation graph
//!   serve  [--patterns 6] [--requests 120] [--workers W] closed-loop serving-trace replay
//!
//! `--theta` defaults to `auto` everywhere: the cost model tunes θ on
//! the matrix's unit histogram via `planner::Planner` — the same path
//! the serving engine uses. `gen:SPEC` synthesizes a matrix, e.g.
//! `gen:powerlaw:4096:12` or `gen:banded:2048:6`,
//! `gen:uniform:4096:0.001`, `gen:blockdiag:2048:24`. Unknown flags
//! are an error; each subcommand lists what it accepts.

use anyhow::{bail, Context, Result};
use libra::balance::BalanceParams;
use libra::costmodel::{self, HardwareProfile};
use libra::dist::{DistParams, Op};
use libra::exec::sddmm::SddmmExecutor;
use libra::exec::{BinaryOp, Reduce, Semiring, SpmmExecutor, TcBackend};
use libra::format::Precision;
use libra::planner::{fmt_theta, Planner, ReorderPolicy, ThetaPolicy};
use libra::serve::{
    Cluster, ClusterConfig, Engine, EngineConfig, MicroBatchParams, MicroBatcher, Request, Routing,
    SchedParams, TenantId,
};
use libra::sparse::{gen, mm_io, Csr, Dense};
use libra::util::SplitMix64;
use std::collections::HashMap;

fn main() -> Result<()> {
    libra::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "spmm" => cmd_spmm(&parse_flags(
            rest,
            &["matrix", "n", "theta", "backend", "seed", "json", "batch", "precision", "reorder"],
        )?),
        "sddmm" => cmd_sddmm(&parse_flags(
            rest,
            &["matrix", "k", "theta", "backend", "seed", "json", "precision", "reorder", "reduce"],
        )?),
        "stats" => cmd_stats(&parse_flags(rest, &["matrix", "seed"])?),
        "tune" => cmd_tune(&parse_flags(rest, &["matrix", "n", "k", "seed"])?),
        "gnn" => cmd_gnn(&parse_flags(
            rest,
            &["model", "epochs", "batch", "graphs", "theta", "reorder", "fused"],
        )?),
        "serve" => cmd_serve(&parse_flags(
            rest,
            &[
                "patterns", "requests", "workers", "n", "size", "theta", "backend", "seed",
                "cache-mb", "batch", "microbatch", "linger-us", "batch-kb", "shards", "tenants",
                "qdepth", "precision", "reorder",
            ],
        )?),
        "--help" | "-h" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn print_usage() {
    println!(
        "libra — heterogeneous sparse matrix multiplication\n\n\
         usage: libra <spmm|sddmm|stats|tune|gnn|serve> [flags]\n\
         \x20 spmm   --matrix <path.mtx|gen:SPEC> [--n 128] [--theta auto|auto-refined|N] [--backend native|pjrt] [--seed 42] [--json]\n\
         \x20        [--precision f32|bf16|f16] [--batch N]  (N>1: compose N member graphs block-diagonally)\n\
         \x20        [--reorder off|auto]  (auto: row-cluster the plan when the density pre-metric fires; not with --batch)\n\
         \x20 sddmm  --matrix <path.mtx|gen:SPEC> [--k 32]  [--theta auto|auto-refined|N] [--backend native|pjrt] [--seed 42] [--json]\n\
         \x20        [--precision f32|bf16|f16] [--reorder off|auto]  (store sparse values bf16/f16-quantized; compute stays f32)\n\
         \x20        [--reduce sum|max|mean]  (per-edge semiring reduction over the feature dim; native backend only)\n\
         \x20 stats  --matrix <path.mtx|gen:SPEC> [--seed 42]\n\
         \x20 tune   [--matrix <path.mtx|gen:SPEC>] [--n 128] [--k 32] [--seed 42]\n\
         \x20 gnn    [--model gcn|agnn] [--epochs 50] [--theta auto|auto-refined|N] [--batch B] [--graphs G]\n\
         \x20        [--reorder off|auto]  (B>0: mini-batch train over G small graphs; --reorder auto is gcn-only)\n\
         \x20        [--fused]  (agnn-only: one-pass SDDMM\u{2192}softmax\u{2192}SpMM attention forward)\n\
         \x20 serve  [--patterns 6] [--requests 120] [--workers W] [--n 64] [--size 1024]\n\
         \x20        [--theta auto|auto-refined|N] [--backend native|pjrt] [--seed 42] [--cache-mb 256] [--batch 8]\n\
         \x20        [--microbatch] [--linger-us 2000] [--batch-kb 2048]  (coalesce requests into block-diagonal batches)\n\
         \x20        [--shards S] [--tenants T] [--qdepth Q]  (scale-out: shard cluster, zipf tenant tags, bounded admission)\n\
         \x20        [--precision f32|bf16|f16]  (precision-qualified plan-cache entries; not with --microbatch)\n\
         \x20        [--reorder off|auto]  (auto: engines row-cluster cached plans when profitable; not with --microbatch)\n\
         gen:SPEC: gen:powerlaw:N:DEG | gen:banded:N:BAND | gen:uniform:N:DENSITY | gen:blockdiag:N:BLOCKS\n\
         (--theta defaults to auto: cost-model tuning on the matrix histogram, one Planner path\n\
         \x20 shared by every subcommand and the serving engine; unknown flags are rejected)"
    );
}

/// Parse `--flag value` / `--flag` pairs, rejecting anything not in
/// `allowed` — an unknown or misspelled flag bails with its name
/// instead of being silently ignored.
fn parse_flags(args: &[String], allowed: &[&str]) -> Result<HashMap<String, String>> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            bail!("unexpected argument '{}' (flags look like --name [value])", args[i]);
        };
        if !allowed.contains(&key) {
            bail!(
                "unknown flag '--{key}' for this subcommand (accepted: {})",
                allowed.iter().map(|f| format!("--{f}")).collect::<Vec<_>>().join(", ")
            );
        }
        let val = args.get(i + 1).filter(|v| !v.starts_with("--"));
        match val {
            Some(v) => {
                map.insert(key.to_string(), v.clone());
                i += 2;
            }
            None => {
                map.insert(key.to_string(), "true".into());
                i += 1;
            }
        }
    }
    Ok(map)
}

fn load_matrix(flags: &HashMap<String, String>) -> Result<Csr> {
    load_matrix_seeded(flags, None)
}

/// Load N member graphs for `--batch N`: a `gen:SPEC` synthesizes N
/// distinct members (seed + i), a file matrix is replicated N times.
fn load_members(flags: &HashMap<String, String>, n_members: usize) -> Result<Vec<Csr>> {
    let base: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    if flags.get("matrix").is_some_and(|s| s.starts_with("gen:")) {
        (0..n_members).map(|i| load_matrix_seeded(flags, Some(base + i as u64))).collect()
    } else {
        let m = load_matrix(flags)?;
        Ok(vec![m; n_members])
    }
}

fn load_matrix_seeded(flags: &HashMap<String, String>, seed: Option<u64>) -> Result<Csr> {
    let spec = flags.get("matrix").context("--matrix required")?;
    if let Some(genspec) = spec.strip_prefix("gen:") {
        let parts: Vec<&str> = genspec.split(':').collect();
        let mut rng = SplitMix64::new(
            seed.or_else(|| flags.get("seed").and_then(|s| s.parse().ok())).unwrap_or(42),
        );
        let n: usize = parts.get(1).and_then(|s| s.parse().ok()).unwrap_or(4096);
        Ok(match parts[0] {
            "powerlaw" => {
                let deg: f64 = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(12.0);
                gen::power_law(&mut rng, n, deg, 2.0)
            }
            "banded" => {
                let band: usize = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
                gen::banded(&mut rng, n, band, 0.6)
            }
            "uniform" => {
                let d: f64 = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(1e-3);
                gen::uniform_random(&mut rng, n, n, d)
            }
            "blockdiag" => {
                let blocks: usize = parts.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
                gen::block_diag_noise(&mut rng, n, blocks, 0.4, 1e-3)
            }
            other => bail!("unknown generator '{other}'"),
        })
    } else {
        mm_io::read_mtx_file(spec)
    }
}

fn backend(flags: &HashMap<String, String>) -> Result<TcBackend> {
    match flags.get("backend").map(String::as_str).unwrap_or("native") {
        "native" => Ok(TcBackend::NativeBitmap),
        "pjrt" => {
            let rt = libra::runtime::Runtime::open_default()
                .context("opening artifacts (run `make artifacts`)")?;
            Ok(TcBackend::Pjrt(std::sync::Arc::new(rt)))
        }
        other => bail!("unknown backend '{other}'"),
    }
}

/// Parse `--theta auto|auto-refined|N` (default: auto).
fn theta_policy(flags: &HashMap<String, String>) -> Result<ThetaPolicy> {
    match flags.get("theta").map(String::as_str) {
        None => Ok(ThetaPolicy::Auto),
        Some(v) => ThetaPolicy::parse(v).ok_or_else(|| {
            anyhow::anyhow!(
                "invalid value '{v}' for --theta (auto, auto-refined, or a positive integer)"
            )
        }),
    }
}

/// Parse `--reorder off|auto` (default: off).
fn reorder_policy(flags: &HashMap<String, String>) -> Result<ReorderPolicy> {
    match flags.get("reorder").map(String::as_str) {
        None => Ok(ReorderPolicy::Off),
        Some(v) => ReorderPolicy::parse(v)
            .ok_or_else(|| anyhow::anyhow!("invalid value '{v}' for --reorder (off or auto)")),
    }
}

/// Parse `--precision f32|bf16|f16` (default: f32).
fn precision(flags: &HashMap<String, String>) -> Result<Precision> {
    match flags.get("precision").map(String::as_str) {
        None => Ok(Precision::F32),
        Some(v) => Precision::parse(v).ok_or_else(|| {
            anyhow::anyhow!("invalid value '{v}' for --precision (f32, bf16, or f16)")
        }),
    }
}

fn cmd_spmm(flags: &HashMap<String, String>) -> Result<()> {
    let batch: usize = flags.get("batch").and_then(|s| s.parse().ok()).unwrap_or(1);
    if batch > 1 {
        return cmd_spmm_batch(flags, batch);
    }
    let m = load_matrix(flags)?;
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(128);
    let json = flags.contains_key("json");
    let prec = precision(flags)?;
    // the full plan path (θ resolution, optional row reorder,
    // distribution, balancing) — identical to what serving runs
    let planner = Planner::new(theta_policy(flags)?).with_reorder(reorder_policy(flags)?);
    let (plan, params) = planner.plan_spmm(&m, n);
    let reordered = plan.perm.is_some();
    let mut exec = SpmmExecutor::from_plan(plan, backend(flags)?);
    if prec != Precision::F32 {
        exec.set_precision(prec);
    }
    if !json {
        println!(
            "matrix {}x{} nnz={} | theta={} ({}) reorder={} -> {} blocks ({:.1}% padding), \
             {} flex nnz",
            m.rows,
            m.cols,
            m.nnz(),
            fmt_theta(params.threshold),
            theta_policy(flags)?,
            if reordered { "applied" } else { "off" },
            exec.dist.stats.n_blocks,
            exec.dist.stats.padding_ratio * 100.0,
            exec.dist.stats.nnz_flex
        );
    }
    let mut rng = SplitMix64::new(1);
    let b = Dense::random(&mut rng, m.cols, n);
    exec.execute(&b)?; // warm
    let t = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        std::hint::black_box(exec.execute(&b)?);
    }
    let secs = t.elapsed().as_secs_f64() / reps as f64;
    let gflops = 2.0 * m.nnz() as f64 * n as f64 / secs / 1e9;
    if json {
        // machine-readable bench point (one JSON object per run)
        println!(
            "{{\"op\":\"spmm\",\"rows\":{},\"cols\":{},\"nnz\":{},\"n\":{n},\"theta\":\"{}\",\
             \"reorder\":{reordered},\"blocks\":{},\"padding_ratio\":{:.6},\"nnz_flex\":{},\
             \"ms\":{:.6},\"gflops\":{:.4},\"pjrt_calls\":{}}}",
            m.rows,
            m.cols,
            m.nnz(),
            fmt_theta(params.threshold),
            exec.dist.stats.n_blocks,
            exec.dist.stats.padding_ratio,
            exec.dist.stats.nnz_flex,
            secs * 1e3,
            gflops,
            exec.counters.snapshot().pjrt_calls
        );
    } else {
        println!(
            "spmm N={n}: {:.3} ms, {:.2} GFLOPS, {} pjrt calls",
            secs * 1e3,
            gflops,
            exec.counters.snapshot().pjrt_calls
        );
    }
    Ok(())
}

/// `spmm --batch N`: compose N member graphs into one block-diagonal
/// batch and compare the per-graph loop (full per-call prep + dispatch
/// per member — what unbatched small-graph traffic pays) against one
/// batched prep + dispatch for the whole set.
fn cmd_spmm_batch(flags: &HashMap<String, String>, n_members: usize) -> Result<()> {
    use libra::prep::{preprocess_spmm_batch, PrepMode};
    use libra::sparse::GraphBatch;
    if reorder_policy(flags)? != ReorderPolicy::Off {
        bail!("--reorder is not supported with --batch (batched plans are window-aligned per member)");
    }
    let members = load_members(flags, n_members)?;
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(128);
    let json = flags.contains_key("json");
    // resolve θ on the composed batch: the members' merged histograms
    // are the supermatrix tuning input; the per-graph loop uses the
    // same parameters so the comparison isolates batching
    let params = Planner::new(theta_policy(flags)?)
        .resolve_batch(&GraphBatch::compose(&members)?, Op::Spmm, n);
    let backend = backend(flags)?;
    let prec = precision(flags)?;
    let nnz: usize = members.iter().map(|m| m.nnz()).sum();
    let mut rng = SplitMix64::new(1);
    let bs: Vec<Dense> = members.iter().map(|m| Dense::random(&mut rng, m.cols, n)).collect();
    let reps = 5;

    // per-graph loop: every member pays distribution + balancing +
    // dispatch on its own
    let t = std::time::Instant::now();
    for _ in 0..reps {
        for (m, b) in members.iter().zip(&bs) {
            let mut exec =
                SpmmExecutor::new(m, &params, &BalanceParams::default(), backend.clone());
            if prec != Precision::F32 {
                exec.set_precision(prec);
            }
            std::hint::black_box(exec.execute(b)?);
        }
    }
    let seq = t.elapsed().as_secs_f64() / reps as f64;

    // batched: one compose + one prep + one hybrid dispatch
    let t = std::time::Instant::now();
    for _ in 0..reps {
        let gb = GraphBatch::compose(&members)?;
        let plan =
            preprocess_spmm_batch(&gb, &params, &BalanceParams::default(), PrepMode::Sequential);
        let mut exec = SpmmExecutor::from_plan(plan.plan, backend.clone());
        if prec != Precision::F32 {
            exec.set_precision(prec);
        }
        std::hint::black_box(exec.execute_batch(&gb, &bs)?);
    }
    let bat = t.elapsed().as_secs_f64() / reps as f64;
    let speedup = seq / bat.max(1e-12);

    if json {
        println!(
            "{{\"op\":\"spmm_batch\",\"members\":{n_members},\"nnz\":{nnz},\"n\":{n},\
             \"theta\":\"{}\",\"per_graph_ms\":{:.6},\"batched_ms\":{:.6},\"speedup\":{:.4}}}",
            fmt_theta(params.threshold),
            seq * 1e3,
            bat * 1e3,
            speedup
        );
    } else {
        println!(
            "spmm batch of {n_members} graphs ({nnz} nnz total), N={n}, theta={}:\n\
             \x20 per-graph loop {:.3} ms | batched {:.3} ms | {:.2}x",
            fmt_theta(params.threshold),
            seq * 1e3,
            bat * 1e3,
            speedup
        );
    }
    Ok(())
}

fn cmd_sddmm(flags: &HashMap<String, String>) -> Result<()> {
    let m = load_matrix(flags)?;
    let k: usize = flags.get("k").and_then(|s| s.parse().ok()).unwrap_or(32);
    let json = flags.contains_key("json");
    let prec = precision(flags)?;
    let planner = Planner::new(theta_policy(flags)?).with_reorder(reorder_policy(flags)?);
    let (plan, params) = planner.plan_sddmm(&m, k);
    let reordered = plan.perm.is_some();
    let mut exec =
        SddmmExecutor::from_plan(plan, std::sync::Arc::new(m.clone()), backend(flags)?);
    if prec != Precision::F32 {
        exec.set_precision(prec);
    }
    if let Some(r) = flags.get("reduce") {
        let reduce = match r.as_str() {
            "sum" => Reduce::Sum,
            "max" => Reduce::Max,
            "mean" => Reduce::Mean,
            other => bail!("invalid value '{other}' for --reduce (sum, max, or mean)"),
        };
        exec.set_semiring(Semiring { op: BinaryOp::Mul, reduce })?;
    }
    let mut rng = SplitMix64::new(2);
    let a = Dense::random(&mut rng, m.rows, k);
    let b = Dense::random(&mut rng, m.cols, k);
    exec.execute(&a, &b)?;
    let t = std::time::Instant::now();
    let reps = 5;
    for _ in 0..reps {
        std::hint::black_box(exec.execute(&a, &b)?);
    }
    let secs = t.elapsed().as_secs_f64() / reps as f64;
    let gflops = 2.0 * m.nnz() as f64 * k as f64 / secs / 1e9;
    if json {
        println!(
            "{{\"op\":\"sddmm\",\"rows\":{},\"cols\":{},\"nnz\":{},\"k\":{k},\"theta\":\"{}\",\
             \"reorder\":{reordered},\"semiring\":\"{}\",\"tc_fraction\":{:.6},\"ms\":{:.6},\
             \"gflops\":{:.4}}}",
            m.rows,
            m.cols,
            m.nnz(),
            fmt_theta(params.threshold),
            exec.semiring,
            exec.dist.stats.tc_fraction(),
            secs * 1e3,
            gflops
        );
    } else {
        println!(
            "sddmm K={k}: theta={} ({}) reorder={} semiring={} | {:.3} ms, {:.2} GFLOPS \
             ({:.1}% nnz structured)",
            fmt_theta(params.threshold),
            theta_policy(flags)?,
            if reordered { "applied" } else { "off" },
            exec.semiring,
            secs * 1e3,
            gflops,
            exec.dist.stats.tc_fraction() * 100.0
        );
    }
    Ok(())
}

fn cmd_stats(flags: &HashMap<String, String>) -> Result<()> {
    let m = load_matrix(flags)?;
    let p = libra::sparse::stats::profile(&m);
    println!("rows={} cols={} nnz={}", p.rows, p.cols, p.nnz);
    println!("avg row len {:.2} (max {}, std {:.2})", p.avg_row_len, p.max_row_len, p.row_len_std);
    println!("nonzero 8x1 vectors: {} (mean nnz {:.2})", p.n_vectors, p.mean_vec_nnz);
    println!("NNZ-1 vector ratio: {:.3}", p.nnz1_ratio);
    let region = if p.nnz1_ratio > 0.75 {
        "flexible-engine advantage"
    } else if p.nnz1_ratio < 0.25 {
        "structured-engine advantage"
    } else {
        "hybrid advantage"
    };
    println!("Fig-1 region: {region}");
    for th in [1usize, 2, 3, 4, 8] {
        let d = libra::dist::distribute_spmm(&m, &DistParams { threshold: th, fill_padding: true });
        println!(
            "  theta={th}: {:.1}% structured, {} blocks, {:.1}% padding",
            d.stats.tc_fraction() * 100.0,
            d.stats.n_blocks,
            d.stats.padding_ratio * 100.0
        );
    }
    Ok(())
}

/// Offline tuning report. Deliberately owns **no** tuning code: every
/// resolved θ below comes from `planner::Planner::resolve` — the exact
/// path `serve::Engine`, `gnn::Trainer`, and the batch composer run —
/// so offline and online tuning can never disagree. (The per-profile
/// analytic crossover is printed for context; it is the model's
/// matrix-independent bound, not a tuning path.)
fn cmd_tune(flags: &HashMap<String, String>) -> Result<()> {
    let n: usize = flags.get("n").and_then(|s| s.parse().ok()).unwrap_or(128);
    let k: usize = flags.get("k").and_then(|s| s.parse().ok()).unwrap_or(32);
    println!("analytic per-unit crossover (matrix-independent):");
    for hw in [HardwareProfile::h100(), HardwareProfile::cpu_substrate()] {
        println!(
            "{:>14}: peak ratio {:>5.1}x  theta_spmm(N={n}) = {}  theta_sddmm(K={k}) = {}",
            hw.name,
            hw.peak_ratio(),
            costmodel::analytic_threshold(&hw, Op::Spmm, n),
            costmodel::analytic_threshold(&hw, Op::Sddmm, k),
        );
    }
    let default_spec = "gen:powerlaw:4096:12";
    let spec = flags.get("matrix").cloned().unwrap_or_else(|| default_spec.to_string());
    let mut with_matrix = flags.clone();
    with_matrix.insert("matrix".into(), spec.clone());
    let m = load_matrix(&with_matrix)?;
    println!(
        "\nPlanner resolution for {spec} ({}x{}, nnz {}) — the serving path:",
        m.rows,
        m.cols,
        m.nnz()
    );
    for policy in [ThetaPolicy::Auto, ThetaPolicy::AutoRefined] {
        let p = Planner::new(policy);
        let ds = p.resolve(&m, Op::Spmm, n);
        let dd = p.resolve(&m, Op::Sddmm, k);
        println!(
            "  {:>12}: theta_spmm(N={n}) = {}  theta_sddmm(K={k}) = {}",
            policy.to_string(),
            fmt_theta(ds.threshold),
            fmt_theta(dd.threshold)
        );
    }
    Ok(())
}

fn cmd_gnn(flags: &HashMap<String, String>) -> Result<()> {
    use libra::gnn::data::planted_partition;
    use libra::gnn::trainer::{train_agnn, train_gcn, TrainConfig, Trainer};
    use libra::gnn::DenseBackend;
    let model = flags.get("model").map(String::as_str).unwrap_or("gcn");
    let epochs: usize = flags.get("epochs").and_then(|s| s.parse().ok()).unwrap_or(50);
    let batch: usize = flags.get("batch").and_then(|s| s.parse().ok()).unwrap_or(0);
    let rp = reorder_policy(flags)?;
    if rp != ReorderPolicy::Off && model != "gcn" {
        bail!("--reorder auto supports only --model gcn (AGNN plans its attention unreordered)");
    }
    let fused = flags.contains_key("fused");
    if fused && model != "agnn" {
        bail!("--fused supports only --model agnn (the fused pass is the attention pipeline)");
    }
    let cfg = TrainConfig {
        epochs,
        lr: 0.01,
        hidden: 64,
        layers: 5,
        reorder: rp,
        fused,
        ..Default::default()
    };
    let policy = theta_policy(flags)?;
    if batch > 0 {
        // mini-batch training over a corpus of small graphs; the
        // trainer resolves θ per composed supermatrix via the Planner
        bail_unless_gcn(model)?;
        let graphs: usize = flags.get("graphs").and_then(|s| s.parse().ok()).unwrap_or(16);
        let corpus: Vec<_> = (0..graphs)
            .map(|i| planted_partition(&format!("mb_{i}"), 200 + 8 * i, 7, 6.0, 0.85, 64, 17))
            .collect();
        let trainer = Trainer::new(cfg, policy, TcBackend::NativeBitmap, DenseBackend::Native);
        let stats = trainer.fit_batched(&corpus, batch)?;
        println!(
            "gcn mini-batch: {graphs} graphs in batches of {batch}, {} epochs, \
             final acc {:.3}, {:.1} ms/epoch, prep {:.2}%",
            epochs,
            stats.final_accuracy,
            stats.total_train_time() / epochs.max(1) as f64 * 1e3,
            stats.prep_fraction() * 100.0
        );
        return Ok(());
    }
    let data = planted_partition("cora_syn", 2708, 7, 6.0, 0.85, 128, 17);
    let params = Planner::new(policy).resolve(&data.adj, Op::Spmm, cfg.hidden);
    let stats = match model {
        "gcn" => train_gcn(&data, &cfg, &params, TcBackend::NativeBitmap, DenseBackend::Native)?,
        "agnn" => train_agnn(&data, &cfg, &params, TcBackend::NativeBitmap, DenseBackend::Native)?,
        other => bail!("unknown model '{other}'"),
    };
    println!(
        "{model}{}: {} epochs, final acc {:.3}, {:.1} ms/epoch, prep {:.2}%",
        if fused { " (fused)" } else { "" },
        epochs,
        stats.final_accuracy,
        stats.total_train_time() / epochs as f64 * 1e3,
        stats.prep_fraction() * 100.0
    );
    Ok(())
}

fn bail_unless_gcn(model: &str) -> Result<()> {
    match model {
        "gcn" => Ok(()),
        other => bail!("--batch supports only --model gcn (got '{other}')"),
    }
}

/// Closed-loop serving driver: synthesizes a multi-tenant request
/// trace (a few distinct sparsity patterns, zipf-skewed popularity,
/// fresh values per request) and replays it against `serve::Engine`,
/// then prints the metrics report — hit rate, latency split, and
/// worker occupancy. With any of `--shards`/`--tenants`/`--qdepth`
/// the trace instead goes through a `serve::Cluster`: requests are
/// tagged with a zipf-skewed `TenantId` (so weighted-fair admission
/// is actually exercised), routed by fingerprint affinity, shed when
/// the bounded queues fill, and reported as one merged
/// `ClusterReport` with per-phase tail percentiles.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    // a value that fails to parse is an error, matching the strict
    // flag-name handling (never silently fall back to a default)
    fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, d: T) -> Result<T> {
        match flags.get(k) {
            None => Ok(d),
            Some(s) => s.parse().map_err(|_| anyhow::anyhow!("invalid value '{s}' for --{k}")),
        }
    }
    let patterns = get(flags, "patterns", 6)?.max(1);
    let requests: usize = get(flags, "requests", 120)?;
    let workers = get(flags, "workers", SchedParams::default().workers)?.max(1);
    let n = get(flags, "n", 64)?.max(1);
    let size = get(flags, "size", 1024)?.max(16);
    let cache_mb: usize = get(flags, "cache-mb", 256)?;
    let batch = get(flags, "batch", 8)?.max(1);
    let seed: u64 = get(flags, "seed", 42)?;
    let microbatch = flags.contains_key("microbatch");
    let prec = precision(flags)?;
    if microbatch && prec != Precision::F32 {
        bail!("--precision is not supported with --microbatch (coalesced batch plans are f32)");
    }
    let rp = reorder_policy(flags)?;
    if microbatch && rp != ReorderPolicy::Off {
        bail!("--reorder is not supported with --microbatch (coalesced batch plans are unreordered)");
    }
    let linger_us: u64 = get(flags, "linger-us", 2000)?;
    let batch_kb: usize = get(flags, "batch-kb", 2048)?.max(1);
    let shards = get(flags, "shards", 1)?.max(1);
    let tenants: usize = get(flags, "tenants", 4)?.max(1);
    let qdepth = get(flags, "qdepth", (workers * 8).max(16))?.max(1);
    // any scale-out flag routes the replay through a sharded Cluster
    let scale_out = flags.contains_key("shards")
        || flags.contains_key("tenants")
        || flags.contains_key("qdepth");

    let mut rng = SplitMix64::new(seed);
    let mats: Vec<Csr> = (0..patterns)
        .map(|i| match i % 3 {
            0 => gen::power_law(&mut rng, size, 8.0, 2.0),
            1 => gen::uniform_random(&mut rng, size, size, (8.0 / size as f64).min(1.0)),
            _ => gen::block_diag_noise(&mut rng, size, (size / 64).max(1), 0.4, 1e-3),
        })
        .collect();
    let policy = theta_policy(flags)?;
    println!(
        "serve: {patterns} patterns ({size}x{size}), {requests} requests, N={n}, theta={policy}, \
         {workers} workers, cache {cache_mb} MiB, batch {batch}{}",
        if microbatch {
            format!(", micro-batching (linger {linger_us} us, {batch_kb} KiB)")
        } else {
            String::new()
        }
    );

    if scale_out {
        println!("scale-out: {shards} shards, {tenants} tenants (zipf tags), qdepth {qdepth}");
        let cluster = Cluster::new(ClusterConfig {
            shards,
            engine: EngineConfig {
                sched: SchedParams { workers, max_batch: batch },
                cache_bytes: cache_mb << 20,
                backend: backend(flags)?,
            },
            qdepth,
            spill_at: (qdepth / 2).max(1),
            routing: Routing::Affinity,
            microbatch: if microbatch {
                Some(MicroBatchParams {
                    max_batch_bytes: batch_kb << 10,
                    linger: std::time::Duration::from_micros(linger_us),
                    theta: policy,
                    dist: None,
                })
            } else {
                None
            },
        });
        for t in 0..tenants {
            cluster.set_tenant_weight(TenantId(t as u32), 1);
        }
        let b = Dense::random(&mut rng, size, n);
        let window = (workers * shards * 4).max(8);
        let mut errors = 0usize;
        let mut shed = 0usize;
        let t0 = std::time::Instant::now();
        if microbatch {
            let mut in_flight = std::collections::VecDeque::with_capacity(window);
            for _ in 0..requests {
                if in_flight.len() >= window {
                    let t: libra::serve::MicroTicket = in_flight.pop_front().unwrap();
                    errors += t.wait().is_err() as usize;
                }
                let mut m = mats[rng.zipf(patterns, 1.8)].clone();
                for v in m.values.iter_mut() {
                    *v = rng.f32_range(-1.0, 1.0);
                }
                match cluster.submit_micro(m, b.clone()) {
                    Ok(t) => in_flight.push_back(t),
                    Err(_) => shed += 1,
                }
            }
            for t in in_flight {
                errors += t.wait().is_err() as usize;
            }
        } else {
            let mut in_flight = std::collections::VecDeque::with_capacity(window);
            for _ in 0..requests {
                if in_flight.len() >= window {
                    let t: libra::serve::ClusterTicket = in_flight.pop_front().unwrap();
                    errors += t.wait().result.is_err() as usize;
                }
                // skewed tenant tags: tenant 0 dominates, the tail is
                // light — the fairness-relevant regime
                let tenant = TenantId(rng.zipf(tenants, 1.2) as u32);
                let mut m = mats[rng.zipf(patterns, 1.8)].clone();
                for v in m.values.iter_mut() {
                    *v = rng.f32_range(-1.0, 1.0);
                }
                let req = Request::spmm(m, b.clone())
                    .with_theta(policy)
                    .with_precision(prec)
                    .with_reorder(rp);
                match cluster.submit_async(tenant, req) {
                    Ok(t) => in_flight.push_back(t),
                    Err(_) => shed += 1,
                }
            }
            for t in in_flight {
                errors += t.wait().result.is_err() as usize;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "replayed {requests} requests ({} admitted, {shed} shed) in {:.2}s ({:.1} req/s)\n",
            requests - shed,
            wall,
            (requests - shed) as f64 / wall.max(1e-9)
        );
        println!("{}", cluster.report());
        if errors > 0 {
            bail!("{errors} requests failed");
        }
        return Ok(());
    }

    let engine = std::sync::Arc::new(Engine::new(EngineConfig {
        sched: SchedParams { workers, max_batch: batch },
        cache_bytes: cache_mb << 20,
        backend: backend(flags)?,
    }));
    let b = Dense::random(&mut rng, size, n);

    // closed loop: at most `window` requests in flight, so queue-wait
    // reflects steady state instead of a t=0 flood
    let window = (workers * 4).max(8);
    let mut errors = 0usize;
    let t0 = std::time::Instant::now();
    let micro_report = if microbatch {
        // micro-batched path: the coalescer owns admission; requests
        // from this (and any other) session merge into block-diagonal
        // supermatrix submissions per feature width
        let batcher = MicroBatcher::new(
            engine.clone(),
            MicroBatchParams {
                max_batch_bytes: batch_kb << 10,
                linger: std::time::Duration::from_micros(linger_us),
                theta: policy,
                dist: None,
            },
        );
        let mut in_flight = std::collections::VecDeque::with_capacity(window);
        for _ in 0..requests {
            if in_flight.len() >= window {
                let t: libra::serve::MicroTicket = in_flight.pop_front().unwrap();
                errors += t.wait().is_err() as usize;
            }
            let which = rng.zipf(patterns, 1.8);
            let mut m = mats[which].clone();
            for v in m.values.iter_mut() {
                *v = rng.f32_range(-1.0, 1.0);
            }
            in_flight.push_back(batcher.submit(m, b.clone()));
        }
        for t in in_flight {
            errors += t.wait().is_err() as usize;
        }
        Some(batcher.report())
    } else {
        let mut in_flight = std::collections::VecDeque::with_capacity(window);
        for _ in 0..requests {
            if in_flight.len() >= window {
                let t: libra::serve::Ticket = in_flight.pop_front().unwrap();
                errors += t.wait().result.is_err() as usize;
            }
            let which = rng.zipf(patterns, 1.8);
            let mut m = mats[which].clone();
            for v in m.values.iter_mut() {
                *v = rng.f32_range(-1.0, 1.0);
            }
            let req = Request::spmm(m, b.clone())
                .with_theta(policy)
                .with_precision(prec)
                .with_reorder(rp);
            in_flight.push_back(engine.submit_async(req));
        }
        for t in in_flight {
            errors += t.wait().result.is_err() as usize;
        }
        None
    };
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "replayed {requests} requests in {:.2}s ({:.1} req/s end-to-end)\n",
        wall,
        requests as f64 / wall.max(1e-9)
    );
    if let Some(rep) = micro_report {
        println!("{rep}");
    }
    println!("{}", engine.report());
    if errors > 0 {
        bail!("{errors} requests failed");
    }
    Ok(())
}
