//! PJRT client wrapper: compiles HLO-text artifacts once and serves
//! typed execute calls from the hot path.
//!
//! Thread-safety: the `xla` crate's handles are `Rc`-based and not
//! `Send`/`Sync`, but the underlying PJRT CPU client is thread-safe.
//! All PJRT state lives behind one `Mutex`, and every operation —
//! including `Rc` refcount manipulation — happens while holding it,
//! which makes the `unsafe impl Send/Sync` below sound. (The CPU
//! client parallelizes *inside* a call, so serializing calls costs
//! little; the structured stream is a single issuing thread anyway.)

use super::manifest::{ArtifactSpec, DType, Manifest};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// A typed input tensor (borrowed host data).
#[derive(Debug, Clone, Copy)]
pub enum Input<'a> {
    F32(&'a [f32]),
    U32(&'a [u32]),
    /// Raw bf16 payload (2 bytes/element, little-endian).
    Bf16(&'a [u16]),
}

impl Input<'_> {
    fn numel(&self) -> usize {
        match self {
            Input::F32(x) => x.len(),
            Input::U32(x) => x.len(),
            Input::Bf16(x) => x.len(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Input::F32(_) => DType::F32,
            Input::U32(_) => DType::U32,
            Input::Bf16(_) => DType::Bf16,
        }
    }

    fn bytes(&self) -> &[u8] {
        // Safe reinterpretation of plain-old-data slices.
        match self {
            Input::F32(x) => unsafe {
                std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4)
            },
            Input::U32(x) => unsafe {
                std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4)
            },
            Input::Bf16(x) => unsafe {
                std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 2)
            },
        }
    }
}

fn element_type(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::U32 => xla::ElementType::U32,
        DType::Bf16 => xla::ElementType::Bf16,
    }
}

/// All non-thread-safe PJRT handles, guarded by the Runtime's mutex.
struct PjrtState {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// The artifact runtime. Compilation is lazy (first use) and cached.
/// `execute_f32` may be called from any thread.
pub struct Runtime {
    state: Mutex<PjrtState>,
    dir: PathBuf,
    pub manifest: Manifest,
    /// Cumulative PJRT calls (for the profiling benches).
    pub calls: std::sync::atomic::AtomicU64,
}

// SAFETY: every access to the Rc-based PJRT handles goes through
// `state: Mutex<PjrtState>`; no handle or clone escapes the lock.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Open an artifact directory (must contain manifest.json).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Self {
            state: Mutex::new(PjrtState { client, exes: HashMap::new() }),
            dir,
            manifest,
            calls: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Default artifact dir: `$LIBRA_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("LIBRA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest.find(name).with_context(|| format!("unknown artifact {name}"))
    }

    /// Eagerly compile every artifact matching `filter` (startup warm-up).
    pub fn warmup(&self, filter: impl Fn(&str) -> bool) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .artifacts
            .iter()
            .map(|a| a.name.clone())
            .filter(|n| filter(n))
            .collect();
        let mut state = self.state.lock().unwrap();
        for n in &names {
            Self::compile_locked(&mut state, &self.dir, n)?;
        }
        Ok(names.len())
    }

    fn compile_locked<'s>(
        state: &'s mut PjrtState,
        dir: &Path,
        name: &str,
    ) -> Result<&'s xla::PjRtLoadedExecutable> {
        if !state.exes.contains_key(name) {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
                .map_err(|e| anyhow::anyhow!("load {name}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = state
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
            state.exes.insert(name.to_string(), exe);
        }
        Ok(state.exes.get(name).unwrap())
    }

    /// Execute an artifact with host inputs; returns each output as a
    /// flat f32 vector (bf16 outputs are widened).
    pub fn execute_f32(&self, name: &str, inputs: &[Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: expected {} inputs, got {}", spec.inputs.len(), inputs.len());
        }
        for (i, (inp, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if inp.numel() != ispec.numel() || inp.dtype() != ispec.dtype {
                bail!(
                    "{name}: input {i} mismatch (got {} {:?}, want {} {:?})",
                    inp.numel(),
                    inp.dtype(),
                    ispec.numel(),
                    ispec.dtype
                );
            }
        }
        let mut state = self.state.lock().unwrap();
        // literals are created under the lock (Literal is Rc-free but
        // the convention keeps all xla objects lock-guarded)
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (inp, ispec)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                element_type(ispec.dtype),
                &ispec.shape,
                inp.bytes(),
            )
            .map_err(|e| anyhow::anyhow!("literal {name}#{i}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = Self::compile_locked(&mut state, &self.dir, name)?;
        self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
        let tuple = lit.to_tuple().map_err(|e| anyhow::anyhow!("tuple {name}: {e:?}"))?;
        let mut outs = Vec::with_capacity(tuple.len());
        for (o, ospec) in tuple.into_iter().zip(&spec.outputs) {
            let v = match ospec.dtype {
                DType::F32 => o.to_vec::<f32>().map_err(|e| anyhow::anyhow!("out: {e:?}"))?,
                DType::Bf16 => {
                    let wide = o
                        .convert(xla::PrimitiveType::F32)
                        .map_err(|e| anyhow::anyhow!("bf16->f32: {e:?}"))?;
                    wide.to_vec::<f32>().map_err(|e| anyhow::anyhow!("out: {e:?}"))?
                }
                DType::U32 => bail!("u32 outputs unsupported"),
            };
            outs.push(v);
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    //! These tests need `make artifacts` to have run; they are skipped
    //! (with a notice) when the artifact directory is absent so `cargo
    //! test` stays green on a fresh checkout.
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping runtime test: no artifacts/ (run `make artifacts`)");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    #[test]
    fn linear_artifact_matches_native() {
        let Some(rt) = runtime() else { return };
        let spec = rt.spec("linear_2048x64x16").unwrap().clone();
        assert_eq!(spec.inputs[0].shape, vec![2048, 64]);
        let x: Vec<f32> = (0..2048 * 64).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
        let w: Vec<f32> = (0..64 * 16).map(|i| ((i % 13) as f32 - 6.0) * 0.05).collect();
        let outs = rt.execute_f32("linear_2048x64x16", &[Input::F32(&x), Input::F32(&w)]).unwrap();
        assert_eq!(outs.len(), 1);
        let y = &outs[0];
        assert_eq!(y.len(), 2048 * 16);
        for j in 0..16 {
            let mut acc = 0f32;
            for k in 0..64 {
                acc += x[3 * 64 + k] * w[k * 16 + j];
            }
            assert!((acc - y[3 * 16 + j]).abs() < 1e-3, "row3 col{j}: {acc} vs {}", y[3 * 16 + j]);
        }
    }

    #[test]
    fn input_validation() {
        let Some(rt) = runtime() else { return };
        let bad = vec![0f32; 10];
        let res = rt.execute_f32("linear_2048x64x16", &[Input::F32(&bad), Input::F32(&bad)]);
        assert!(res.is_err());
        assert!(rt.spec("nonexistent").is_err());
    }

    #[test]
    fn spmm_bitmap_artifact_runs() {
        let Some(rt) = runtime() else { return };
        let g = 256;
        let mut bm = vec![0u32; g * 2];
        bm[0] = 1;
        let mut vals = vec![0f32; g * 64];
        vals[0] = 2.0;
        let mut b = vec![0f32; g * 8 * 32];
        for j in 0..32 {
            b[j] = 1.0;
        }
        let outs = rt
            .execute_f32(
                "spmm_tc_bitmap_256x32",
                &[Input::U32(&bm), Input::F32(&vals), Input::F32(&b)],
            )
            .unwrap();
        let y = &outs[0];
        for j in 0..32 {
            assert!((y[j] - 2.0).abs() < 1e-5);
        }
        assert!(y[32..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn concurrent_execution_is_safe() {
        let Some(rt) = runtime() else { return };
        let rt = std::sync::Arc::new(rt);
        crossbeam_utils::thread::scope(|s| {
            for t in 0..4 {
                let rt = rt.clone();
                s.spawn(move |_| {
                    let x = vec![t as f32; 2048 * 64];
                    let w = vec![1.0f32; 64 * 16];
                    let outs = rt
                        .execute_f32("linear_2048x64x16", &[Input::F32(&x), Input::F32(&w)])
                        .unwrap();
                    assert!((outs[0][0] - (t as f32) * 64.0).abs() < 1e-2);
                });
            }
        })
        .unwrap();
    }
}
