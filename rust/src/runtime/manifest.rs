//! Artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime (shapes + dtypes per artifact).

use super::json::{parse, Json};
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Element type of a tensor crossing the PJRT boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    U32,
    Bf16,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "u32" => DType::U32,
            "bf16" => DType::Bf16,
            other => bail!("unknown dtype {other}"),
        })
    }

    pub fn byte_size(&self) -> usize {
        match self {
            DType::F32 | DType::U32 => 4,
            DType::Bf16 => 2,
        }
    }
}

/// Shape + dtype of one input/output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: name + I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.json.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn parse_str(text: &str) -> Result<Self> {
        let v = parse(text).context("manifest json")?;
        let arts = v
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing 'artifacts'")?;
        let mut out = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a.get("name").and_then(Json::as_str).context("artifact name")?.to_string();
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                a.get(key)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("{name}: missing {key}"))?
                    .iter()
                    .map(|t| {
                        let shape = t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("shape")?
                            .iter()
                            .map(|d| d.as_usize().context("dim"))
                            .collect::<Result<Vec<_>>>()?;
                        let s = t.get("dtype").and_then(Json::as_str).context("dtype")?;
                        let dtype = DType::parse(s)?;
                        Ok(TensorSpec { shape, dtype })
                    })
                    .collect()
            };
            let inputs = parse_tensors("inputs")?;
            let outputs = parse_tensors("outputs")?;
            out.push(ArtifactSpec { name, inputs, outputs });
        }
        Ok(Manifest { artifacts: out })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read manifest {}", path.display()))?;
        Self::parse_str(&text)
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": [
        {"name": "spmm_tc_bitmap_256x32",
         "inputs": [{"shape": [256, 2], "dtype": "u32"},
                    {"shape": [256, 64], "dtype": "f32"},
                    {"shape": [256, 8, 32], "dtype": "f32"}],
         "outputs": [{"shape": [256, 8, 32], "dtype": "f32"}]}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse_str(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("spmm_tc_bitmap_256x32").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].dtype, DType::U32);
        assert_eq!(a.inputs[2].numel(), 256 * 8 * 32);
        assert_eq!(a.outputs[0].shape, vec![256, 8, 32]);
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse_str("{}").is_err());
        assert!(Manifest::parse_str(r#"{"artifacts": [{"name": "x"}]}"#).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("bf16").unwrap().byte_size(), 2);
        assert!(DType::parse("f64").is_err());
    }
}
