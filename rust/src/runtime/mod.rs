//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python is never on the request path — `make artifacts` runs once at
//! build time; afterwards this module compiles the HLO-text files on
//! the embedded PJRT CPU client and serves typed `execute` calls.

pub mod client;
pub mod json;
pub mod manifest;

pub use client::{Input, Runtime};
pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};
