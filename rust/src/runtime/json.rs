//! Minimal JSON parser (offline stand-in for `serde_json`), sufficient
//! for the artifact manifest and config files: objects, arrays,
//! strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// `obj["key"]` convenience.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl fmt::Display) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.to_string() })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.s.len() && self.s[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.s[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.s.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.s[self.pos + 1..self.pos + 5])
                                .map_err(|_| JsonError { pos: self.pos, msg: "bad utf8".into() })?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError { pos: self.pos, msg: "bad hex".into() })?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.s[self.pos..])
                        .map_err(|_| JsonError { pos: self.pos, msg: "bad utf8".into() })?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let is_num = |c: u8| {
            c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E' || c == b'+' || c == b'-'
        };
        while matches!(self.peek(), Some(c) if is_num(c)) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { s: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.s.len() {
        return p.err("trailing characters");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n \"x\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("x").unwrap().as_arr().unwrap().len(), 2);
    }
}
