//! Property suite for the affinity-reorder plan stage.
//!
//! Three guarantees are checked across the adversarial
//! `testgen::pattern_family` mix, with forced random permutations (not
//! just the ones `reorder::decide` would pick — any valid permutation
//! must round-trip):
//!
//! - **Fold exactness.** A reordered plan executes in permuted row
//!   space and row-scatters the result back; at deterministic executor
//!   configs the output must be bit-identical to manually scattering a
//!   plain execution of the permuted matrix. For the flexible-only
//!   extreme the fold is bit-identical to the *unreordered* execution
//!   outright (per-row chunk boundaries depend only on the row's own
//!   length, so permutation cannot change any accumulation order). The
//!   hybrid/TC paths are exempt from that stronger claim by design:
//!   window regrouping changes which columns share a TC block, which
//!   reassociates the f32 block partials.
//! - **SDDMM schedule invariance.** The sampled-dot kernel is a pure
//!   function of its operand rows and the reordered plan's output
//!   indices are remapped to original CSR positions at build time, so
//!   reordered SDDMM output is bit-identical to unreordered at any θ.
//! - **`ReorderPolicy::Off` is inert.** A planner with the stage off
//!   must produce plans byte-identical to the direct preprocess
//!   pipeline, with no permutation attached.
//!
//! Plus the serving contract: reordered plans are cached under
//! reorder-qualified keys and repeat traffic warm-hits them, while
//! `off` traffic for the same pattern builds (and then hits) its own
//! separate entry.

use libra::balance::BalanceParams;
use libra::dist::DistParams;
use libra::exec::sddmm::SddmmExecutor;
use libra::exec::{SpmmExecutor, TcBackend, Threading};
use libra::planner::{Planner, ReorderPolicy, ThetaPolicy};
use libra::prep::{
    preprocess_sddmm, preprocess_sddmm_reordered, preprocess_spmm, preprocess_spmm_reordered,
    PrepMode,
};
use libra::reorder::RowPerm;
use libra::serve::{Engine, EngineConfig, Request, SchedParams};
use libra::sparse::{gen, Dense};
use libra::util::propcheck::{check, Config};
use libra::util::{testgen, SplitMix64};

fn random_perm(rng: &mut SplitMix64, rows: usize) -> RowPerm {
    let mut order: Vec<u32> = (0..rows as u32).collect();
    rng.shuffle(&mut order);
    RowPerm::from_perm(order)
}

fn random_dist_params(rng: &mut SplitMix64) -> DistParams {
    match rng.below(4) {
        0 => DistParams::default(),
        1 => DistParams::flex_only(),
        2 => DistParams::tc_only(),
        _ => DistParams { threshold: rng.range(1, 10), fill_padding: rng.chance(0.5) },
    }
}

fn random_balance_params(rng: &mut SplitMix64) -> BalanceParams {
    if rng.chance(0.3) {
        BalanceParams::default()
    } else {
        BalanceParams {
            ts: rng.range(1, 8),
            cs: rng.range(2, 40),
            short_len: rng.range(1, 6),
            enabled: rng.chance(0.8),
        }
    }
}

/// Deterministic single-stream executor: inline threading, one
/// flexible stream, so every accumulation order is fixed.
fn deterministic(e: &mut SpmmExecutor) {
    e.flex_threads = 1;
    e.threading = Threading::Inline;
}

#[test]
fn reordered_spmm_fold_matches_manual_scatter() {
    check(Config::default().cases(24), "reorder fold == manual scatter", |rng| {
        let m = testgen::pattern_family(rng, 96);
        let perm = random_perm(rng, m.rows);
        let d = random_dist_params(rng);
        let bal = random_balance_params(rng);
        let n = rng.range(1, 12);
        let b = Dense::random(rng, m.cols, n);

        let mut folded = SpmmExecutor::from_plan(
            preprocess_spmm_reordered(&m, &d, &bal, PrepMode::Sequential, &perm),
            TcBackend::NativeBitmap,
        );
        deterministic(&mut folded);
        assert!(folded.perm.is_some());
        let got = folded.execute(&b).unwrap();

        // plain execution of the permuted matrix, scattered by hand
        let permuted = perm.apply_rows(&m);
        let mut plain = SpmmExecutor::from_plan(
            preprocess_spmm(&permuted, &d, &bal, PrepMode::Sequential),
            TcBackend::NativeBitmap,
        );
        deterministic(&mut plain);
        let tmp = plain.execute(&b).unwrap();
        let mut want = Dense::zeros(m.rows, n);
        for (new, &old) in perm.perm.iter().enumerate() {
            let dst = old as usize * n;
            want.data[dst..dst + n].copy_from_slice(&tmp.data[new * n..(new + 1) * n]);
        }
        assert_eq!(got.data, want.data, "inverse fold diverged from manual scatter");
    });
}

#[test]
fn reordered_spmm_bit_identical_at_flex_only() {
    // at the flexible-only extreme the whole claim strengthens to
    // bit-identity against the *unreordered* execution: tile chunk
    // boundaries are a function of each row's own length, so the
    // permutation cannot reassociate any per-row sum
    check(Config::default().cases(24), "flex-only reorder == unreordered", |rng| {
        let m = testgen::pattern_family(rng, 96);
        let perm = random_perm(rng, m.rows);
        let d = DistParams::flex_only();
        let bal = random_balance_params(rng);
        let n = rng.range(1, 12);
        let b = Dense::random(rng, m.cols, n);

        let mut reord = SpmmExecutor::from_plan(
            preprocess_spmm_reordered(&m, &d, &bal, PrepMode::Sequential, &perm),
            TcBackend::NativeBitmap,
        );
        let mut plain = SpmmExecutor::from_plan(
            preprocess_spmm(&m, &d, &bal, PrepMode::Sequential),
            TcBackend::NativeBitmap,
        );
        deterministic(&mut reord);
        deterministic(&mut plain);
        let got = reord.execute(&b).unwrap();
        let want = plain.execute(&b).unwrap();
        assert_eq!(got.data, want.data, "flex-only reordered output diverged");
    });
}

#[test]
fn reordered_sddmm_bit_identical_at_any_theta() {
    check(Config::default().cases(20), "reordered sddmm == unreordered", |rng| {
        let m = testgen::pattern_family(rng, 80);
        let perm = random_perm(rng, m.rows);
        let d = match rng.below(3) {
            0 => DistParams::sddmm_default(),
            1 => DistParams::flex_only(),
            _ => DistParams { threshold: rng.range(1, 48), fill_padding: true },
        };
        let bal = random_balance_params(rng);
        let k = rng.range(1, 10);
        let a = Dense::random(rng, m.rows, k);
        let b = Dense::random(rng, m.cols, k);

        let reord = SddmmExecutor::from_plan(
            preprocess_sddmm_reordered(&m, &d, &bal, PrepMode::Sequential, &perm),
            std::sync::Arc::new(m.clone()),
            TcBackend::NativeBitmap,
        );
        let plain = SddmmExecutor::from_plan(
            preprocess_sddmm(&m, &d, &bal, PrepMode::Sequential),
            std::sync::Arc::new(m.clone()),
            TcBackend::NativeBitmap,
        );
        let got = reord.execute(&a, &b).unwrap();
        let want = plain.execute(&a, &b).unwrap();
        assert_eq!(got.values, want.values, "reordered SDDMM output diverged");
    });
}

#[test]
fn policy_off_is_byte_identical_to_direct_preprocess() {
    check(Config::default().cases(16), "reorder off == direct pipeline", |rng| {
        let m = testgen::pattern_family(rng, 96);
        let n = rng.range(1, 16);
        let planner = Planner::new(ThetaPolicy::Auto).with_reorder(ReorderPolicy::Off);

        let (plan, d) = planner.plan_spmm(&m, n);
        assert!(plan.perm.is_none(), "Off must never attach a permutation");
        let want = preprocess_spmm(&m, &d, &BalanceParams::default(), PrepMode::Sequential);
        assert_eq!(plan.dist.tc.window_of, want.dist.tc.window_of);
        assert_eq!(plan.dist.tc.cols, want.dist.tc.cols);
        assert_eq!(plan.dist.tc.bitmaps, want.dist.tc.bitmaps);
        assert_eq!(plan.dist.tc.values, want.dist.tc.values);
        assert_eq!(plan.dist.tc_src_idx, want.dist.tc_src_idx);
        assert_eq!(plan.dist.flex_row_ptr, want.dist.flex_row_ptr);
        assert_eq!(plan.dist.flex_cols, want.dist.flex_cols);
        assert_eq!(plan.dist.flex_vals, want.dist.flex_vals);
        assert_eq!(plan.dist.flex_src_idx, want.dist.flex_src_idx);
        assert_eq!(plan.dist.stats, want.dist.stats);
        assert_eq!(plan.sched.long_tiles, want.sched.long_tiles);
        assert_eq!(plan.sched.short_tiles, want.sched.short_tiles);
        assert_eq!(plan.sched.tc_segments, want.sched.tc_segments);

        let (splan, sd) = planner.plan_sddmm(&m, n);
        assert!(splan.perm.is_none(), "Off must never attach a permutation");
        let swant = preprocess_sddmm(&m, &sd, &BalanceParams::default(), PrepMode::Sequential);
        assert_eq!(splan.dist.tc.bitmaps, swant.dist.tc.bitmaps);
        assert_eq!(splan.dist.tc.values, swant.dist.tc.values);
        assert_eq!(splan.dist.tc_out_idx, swant.dist.tc_out_idx);
        assert_eq!(splan.dist.flex_rows, swant.dist.flex_rows);
        assert_eq!(splan.dist.flex_cols, swant.dist.flex_cols);
        assert_eq!(splan.dist.flex_out_idx, swant.dist.flex_out_idx);
        assert_eq!(splan.dist.stats, swant.dist.stats);
    });
}

#[test]
fn reordered_plans_warm_hit_the_serve_cache() {
    let eng = Engine::new(EngineConfig {
        sched: SchedParams { workers: 2, max_batch: 8 },
        cache_bytes: 64 << 20,
        backend: TcBackend::NativeBitmap,
    });
    // a shuffled column-clustered pattern: the affinity pre-metric
    // demonstrably fires on it (same construction as the reorder-stage
    // unit tests)
    let mut rng = SplitMix64::new(77);
    let base = gen::column_clustered(&mut rng, 256, 256, 4_000, 0.85, 8);
    let m = random_perm(&mut rng, base.rows).apply_rows(&base);
    let b = Dense::random(&mut rng, 256, 16);

    // cold: the pre-metric runs once, the plan lands under a
    // reorder-qualified key
    let cold = eng.submit(Request::spmm(m.clone(), b.clone()).with_reorder(ReorderPolicy::Auto));
    assert!(!cold.cache_hit);
    let got = cold.result.unwrap().into_dense().unwrap();
    assert!(got.allclose(&m.spmm_dense_ref(&b), 1e-3));

    // repeat traffic, fresh values each time: all warm, and the memoed
    // decision means the pre-metric never reruns
    for session in 0..3 {
        let mut m2 = m.clone();
        for v in m2.values.iter_mut() {
            *v = rng.f32_range(-2.0, 2.0);
        }
        let r = eng.submit(Request::spmm(m2.clone(), b.clone()).with_reorder(ReorderPolicy::Auto));
        assert!(r.cache_hit, "session {session} must warm-hit the reordered plan");
        let out = r.result.unwrap().into_dense().unwrap();
        assert!(out.allclose(&m2.spmm_dense_ref(&b), 1e-3));
    }
    let rep = eng.report();
    assert_eq!(rep.reorder_applied, 1, "the pre-metric must run exactly once");
    assert_eq!(rep.reorder_skipped, 0);
    assert_eq!(rep.prep_full, 1);
    assert_eq!(rep.prep_fast, 3);

    // the same pattern served with the stage off is a different key:
    // one more cold build, then its own warm hits
    let off = eng.submit(Request::spmm(m.clone(), b.clone()));
    assert!(!off.cache_hit, "off traffic must not hit the reordered entry");
    let off2 = eng.submit(Request::spmm(m.clone(), b.clone()));
    assert!(off2.cache_hit);
    assert_eq!(eng.report().prep_full, 2);
}
