//! End-to-end serving smoke: engine up, several sessions over one
//! pattern (cold then warm, asserted via prep metrics), then an edge
//! delta submitted through [`Engine::submit_delta`] and served warm —
//! the evolving-graph path must be a patch, never a silent rebuild.
//! The scale-out half does the same through a sharded [`Cluster`]:
//! affinity routing must pin a pattern's warm hits to one home shard,
//! full admission queues must shed with an explicit
//! [`Rejected::QueueFull`] instead of blocking, and routing must stay
//! deterministic and shard-stable under `apply_delta`
//! re-fingerprinting.

use libra::delta::EdgeDelta;
use libra::exec::TcBackend;
use libra::serve::{
    Cluster, ClusterConfig, DeltaRequest, Engine, EngineConfig, Rejected, Request, Routing,
    SchedParams, TenantId,
};
use libra::sparse::{gen, Dense};
use libra::util::propcheck::{check, Config};
use libra::util::SplitMix64;

fn mk_cluster(shards: usize, qdepth: usize, spill_at: usize) -> Cluster {
    Cluster::new(ClusterConfig {
        shards,
        engine: EngineConfig {
            sched: SchedParams { workers: 1, max_batch: 8 },
            cache_bytes: 64 << 20,
            backend: TcBackend::NativeBitmap,
        },
        qdepth,
        spill_at,
        routing: Routing::Affinity,
        microbatch: None,
    })
}

#[test]
fn serve_smoke_warm_sessions_then_delta() {
    let eng = Engine::new(EngineConfig {
        sched: SchedParams { workers: 2, max_batch: 8 },
        cache_bytes: 64 << 20,
        backend: TcBackend::NativeBitmap,
    });
    let mut rng = SplitMix64::new(2024);
    let m = gen::power_law(&mut rng, 256, 8.0, 2.0);
    let b = Dense::random(&mut rng, 256, 16);

    // session 1: cold — full preprocessing
    let cold = eng.submit(Request::spmm(m.clone(), b.clone()));
    assert!(!cold.cache_hit);
    let got = cold.result.unwrap().into_dense().unwrap();
    assert!(got.allclose(&m.spmm_dense_ref(&b), 1e-3));

    // sessions 2..=5: same pattern, fresh values — all warm
    for session in 0..4 {
        let mut m2 = m.clone();
        for v in m2.values.iter_mut() {
            *v = rng.f32_range(-2.0, 2.0);
        }
        let r = eng.submit(Request::spmm(m2.clone(), b.clone()));
        assert!(r.cache_hit, "session {session} must hit the plan cache");
        let out = r.result.unwrap().into_dense().unwrap();
        assert!(out.allclose(&m2.spmm_dense_ref(&b), 1e-3));
    }
    let rep = eng.report();
    assert_eq!(rep.prep_full, 1, "exactly one cold prep");
    assert_eq!(rep.prep_fast, 4, "all follow-up sessions must be warm");
    assert_eq!(rep.errors, 0);

    // now the graph evolves: one structural insertion + one deletion
    let fp = m.pattern_fingerprint();
    let ins = (0..m.cols).find(|&c| m.get(3, c).is_none()).unwrap();
    let del_row = (0..m.rows).find(|&row| m.row_len(row) > 0).unwrap();
    let del_col = m.row(del_row).0[0] as usize;
    let mut delta = EdgeDelta::new();
    delta.upsert(3, ins, 0.75).delete(del_row, del_col);
    let new_m = m.apply_delta(&delta).unwrap();

    let out = eng.submit_delta(DeltaRequest::spmm(fp, delta, 16)).unwrap();
    assert!(out.patched, "served pattern must be patched, not rebuilt");
    assert_eq!(out.new_fp, new_m.pattern_fingerprint());
    assert_eq!(out.nnz, new_m.nnz());
    let rep = eng.report();
    assert_eq!(rep.delta_patched, 1);
    assert_eq!(rep.delta_rebuilt, 0);

    // the patched plan serves the mutated graph warm: no new full prep
    let r = eng.submit(Request::spmm(new_m.clone(), b.clone()));
    assert!(r.cache_hit, "post-delta request must hit the patched plan");
    let out = r.result.unwrap().into_dense().unwrap();
    assert!(out.allclose(&new_m.spmm_dense_ref(&b), 1e-3));
    let rep = eng.report();
    assert_eq!(rep.prep_full, 1, "the delta must not trigger a cold prep");
}

#[test]
fn cluster_smoke_warm_hits_stay_on_the_home_shard() {
    // spill_at > qdepth: sequential blocking submits never spill, so
    // every request for one pattern must land on its home shard
    let cluster = mk_cluster(4, 16, 64);
    let mut rng = SplitMix64::new(2025);
    let m = gen::power_law(&mut rng, 256, 8.0, 2.0);
    let b = Dense::random(&mut rng, 256, 16);
    let home = cluster.home_shard(m.pattern_fingerprint());

    // cold: exactly one full prep, on the home shard
    let cold = cluster.submit(TenantId(0), Request::spmm(m.clone(), b.clone())).unwrap();
    assert!(!cold.cache_hit);
    assert!(cold.result.unwrap().into_dense().unwrap().allclose(&m.spmm_dense_ref(&b), 1e-3));
    assert_eq!(cluster.shard_engine(home).report().prep_full, 1, "cold prep on home shard");

    // 4 repeats with fresh values: all warm, all on the SAME shard
    for session in 0..4 {
        let mut m2 = m.clone();
        for v in m2.values.iter_mut() {
            *v = rng.f32_range(-2.0, 2.0);
        }
        let t = cluster.submit_async(TenantId(0), Request::spmm(m2, b.clone())).unwrap();
        assert_eq!(t.shard(), home, "repeat {session} must route to the home shard");
        assert!(t.wait().cache_hit, "repeat {session} must hit the home shard's cache");
    }
    let home_rep = cluster.shard_engine(home).report();
    assert_eq!(home_rep.prep_full, 1);
    assert_eq!(home_rep.prep_fast, 4, "every repeat warm on the home shard");
    for i in (0..4).filter(|&i| i != home) {
        assert_eq!(cluster.shard_engine(i).report().requests, 0, "shard {i} must stay idle");
    }
    let rep = cluster.report();
    assert_eq!(rep.merged.requests, 5);
    assert_eq!(rep.spilled, 0);
    assert!((rep.warm_hit_rate() - 0.8).abs() < 1e-9);
}

#[test]
fn cluster_full_queue_sheds_instead_of_blocking() {
    // 1 shard, 1 worker (= 1 runner), qdepth 2, no spill target: once
    // the runner is busy and both queue slots are held, the next offer
    // must come back QueueFull immediately — never block the submitter
    let cluster = mk_cluster(1, 2, 64);
    let mut rng = SplitMix64::new(2026);
    let m = gen::power_law(&mut rng, 512, 12.0, 2.0);
    let b = Dense::random(&mut rng, 512, 64);
    let fresh = |rng: &mut SplitMix64| {
        let mut m2 = m.clone();
        for v in m2.values.iter_mut() {
            *v = rng.f32_range(-1.0, 1.0);
        }
        m2
    };

    let t1 = cluster.submit_async(TenantId(0), Request::spmm(fresh(&mut rng), b.clone())).unwrap();
    // wait for the runner to pick the first request up (it then blocks
    // in the engine for the whole prep+exec, i.e. milliseconds)
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    while cluster.pending(0) > 0 {
        assert!(std::time::Instant::now() < deadline, "runner never took the first request");
        std::thread::yield_now();
    }
    let t2 = cluster.submit_async(TenantId(0), Request::spmm(fresh(&mut rng), b.clone())).unwrap();
    let t3 = cluster.submit_async(TenantId(0), Request::spmm(fresh(&mut rng), b.clone())).unwrap();
    // both queue slots held -> the fourth submission is shed, with the
    // shard and bound named in the rejection
    let err = cluster
        .submit_async(TenantId(0), Request::spmm(fresh(&mut rng), b.clone()))
        .err()
        .expect("offer past qdepth must be rejected");
    assert_eq!(err, Rejected::QueueFull { shard: 0, depth: 2, limit: 2 });
    for t in [t1, t2, t3] {
        t.wait().result.unwrap();
    }
    let rep = cluster.report();
    assert_eq!(rep.merged.requests, 3, "shed requests never reach the engine");
    assert_eq!(rep.rejected, 1);
    assert_eq!(rep.tenants[0].admitted, 3);
    assert_eq!(rep.tenants[0].rejected, 1);
}

#[test]
fn routing_is_deterministic_and_shard_stable_under_deltas() {
    check(Config::default().cases(6), "cluster routing stability", |rng| {
        let c1 = mk_cluster(4, 16, 64);
        let c2 = mk_cluster(4, 16, 64);
        let m = gen::power_law(rng, 96, 6.0, 2.0);
        let b = Dense::random(rng, 96, 8);
        let fp = m.pattern_fingerprint();
        // determinism: independent cluster instances agree on the home
        let home = c1.home_shard(fp);
        assert_eq!(home, c2.home_shard(fp), "instances must agree on first sight");
        assert_eq!(home, c1.home_shard(fp), "re-asking must not move the pattern");

        // serve it (caches plan + pattern state on the home shard),
        // then mutate the structure through the cluster delta path
        c1.submit(TenantId(0), Request::spmm(m.clone(), b.clone())).unwrap().result.unwrap();
        let row = rng.range(0, m.rows);
        let ins = (0..m.cols).find(|&c| m.get(row, c).is_none()).unwrap();
        let mut delta = EdgeDelta::new();
        delta.upsert(row, ins, 0.5);
        let out = c1.submit_delta(DeltaRequest::spmm(fp, delta, 8)).unwrap();
        assert_ne!(out.new_fp, fp, "the insertion must re-fingerprint the pattern");
        // shard stability: the patched fingerprint inherits the home,
        // even when raw HRW would have placed it elsewhere
        assert_eq!(
            c1.home_shard(out.new_fp),
            home,
            "delta re-fingerprinting must not move the pattern off its home shard"
        );
    });
}
