//! End-to-end serving smoke: engine up, several sessions over one
//! pattern (cold then warm, asserted via prep metrics), then an edge
//! delta submitted through [`Engine::submit_delta`] and served warm —
//! the evolving-graph path must be a patch, never a silent rebuild.

use libra::delta::EdgeDelta;
use libra::exec::TcBackend;
use libra::serve::{DeltaRequest, Engine, EngineConfig, Request, SchedParams};
use libra::sparse::{gen, Dense};
use libra::util::SplitMix64;

#[test]
fn serve_smoke_warm_sessions_then_delta() {
    let eng = Engine::new(EngineConfig {
        sched: SchedParams { workers: 2, max_batch: 8 },
        cache_bytes: 64 << 20,
        backend: TcBackend::NativeBitmap,
    });
    let mut rng = SplitMix64::new(2024);
    let m = gen::power_law(&mut rng, 256, 8.0, 2.0);
    let b = Dense::random(&mut rng, 256, 16);

    // session 1: cold — full preprocessing
    let cold = eng.submit(Request::spmm(m.clone(), b.clone()));
    assert!(!cold.cache_hit);
    let got = cold.result.unwrap().into_dense().unwrap();
    assert!(got.allclose(&m.spmm_dense_ref(&b), 1e-3));

    // sessions 2..=5: same pattern, fresh values — all warm
    for session in 0..4 {
        let mut m2 = m.clone();
        for v in m2.values.iter_mut() {
            *v = rng.f32_range(-2.0, 2.0);
        }
        let r = eng.submit(Request::spmm(m2.clone(), b.clone()));
        assert!(r.cache_hit, "session {session} must hit the plan cache");
        let out = r.result.unwrap().into_dense().unwrap();
        assert!(out.allclose(&m2.spmm_dense_ref(&b), 1e-3));
    }
    let rep = eng.report();
    assert_eq!(rep.prep_full, 1, "exactly one cold prep");
    assert_eq!(rep.prep_fast, 4, "all follow-up sessions must be warm");
    assert_eq!(rep.errors, 0);

    // now the graph evolves: one structural insertion + one deletion
    let fp = m.pattern_fingerprint();
    let ins = (0..m.cols).find(|&c| m.get(3, c).is_none()).unwrap();
    let del_row = (0..m.rows).find(|&row| m.row_len(row) > 0).unwrap();
    let del_col = m.row(del_row).0[0] as usize;
    let mut delta = EdgeDelta::new();
    delta.upsert(3, ins, 0.75).delete(del_row, del_col);
    let new_m = m.apply_delta(&delta).unwrap();

    let out = eng.submit_delta(DeltaRequest::spmm(fp, delta, 16)).unwrap();
    assert!(out.patched, "served pattern must be patched, not rebuilt");
    assert_eq!(out.new_fp, new_m.pattern_fingerprint());
    assert_eq!(out.nnz, new_m.nnz());
    let rep = eng.report();
    assert_eq!(rep.delta_patched, 1);
    assert_eq!(rep.delta_rebuilt, 0);

    // the patched plan serves the mutated graph warm: no new full prep
    let r = eng.submit(Request::spmm(new_m.clone(), b.clone()));
    assert!(r.cache_hit, "post-delta request must hit the patched plan");
    let out = r.result.unwrap().into_dense().unwrap();
    assert!(out.allclose(&new_m.spmm_dense_ref(&b), 1e-3));
    let rep = eng.report();
    assert_eq!(rep.prep_full, 1, "the delta must not trigger a cold prep");
}
