//! Differential harness for incremental plan maintenance.
//!
//! Every property here chains K random edge-batch deltas through the
//! incremental patchers (`Csr::apply_delta`, `PatternDigests::update`,
//! `SpmmPlan::apply_delta` / `SddmmPlan::apply_delta`) and demands the
//! result be **bit-identical** — every distribution array, every
//! balance segment, the fingerprint, and the executed output — to a
//! from-scratch preprocess of the final matrix. Any divergence in any
//! layer is a correctness bug, not a tolerance question: patched plans
//! are served to tenants as if they were cold-built.

use libra::balance::BalanceParams;
use libra::delta::EdgeDelta;
use libra::dist::DistParams;
use libra::exec::sddmm::SddmmExecutor;
use libra::exec::{SpmmExecutor, TcBackend, Threading};
use libra::prep::{preprocess_sddmm, preprocess_spmm, PrepMode, SddmmPlan, SpmmPlan};
use libra::sparse::{Csr, Dense, PatternDigests};
use libra::util::propcheck::{check, Config};
use libra::util::{testgen, SplitMix64};

fn random_dist_params(rng: &mut SplitMix64) -> DistParams {
    match rng.below(4) {
        0 => DistParams::default(),
        1 => DistParams::flex_only(),
        2 => DistParams::tc_only(),
        _ => DistParams { threshold: rng.range(1, 10), fill_padding: rng.chance(0.5) },
    }
}

fn random_sddmm_dist_params(rng: &mut SplitMix64) -> DistParams {
    match rng.below(3) {
        0 => DistParams::sddmm_default(),
        1 => DistParams::flex_only(),
        _ => DistParams { threshold: rng.range(1, 48), fill_padding: true },
    }
}

fn random_balance_params(rng: &mut SplitMix64) -> BalanceParams {
    if rng.chance(0.3) {
        BalanceParams::default()
    } else {
        BalanceParams {
            ts: rng.range(1, 8),
            cs: rng.range(2, 40),
            short_len: rng.range(1, 6),
            enabled: rng.chance(0.8),
        }
    }
}

/// Field-by-field bit-identity of a patched SpMM plan vs a scratch one.
fn assert_spmm_plans_equal(got: &SpmmPlan, want: &SpmmPlan, ctx: &str) {
    assert_eq!(got.dist.rows, want.dist.rows, "{ctx}: rows");
    assert_eq!(got.dist.cols, want.dist.cols, "{ctx}: cols");
    assert_eq!(got.dist.tc.k, want.dist.tc.k, "{ctx}: tc.k");
    assert_eq!(got.dist.tc.window_of, want.dist.tc.window_of, "{ctx}: tc.window_of");
    assert_eq!(got.dist.tc.cols, want.dist.tc.cols, "{ctx}: tc.cols");
    assert_eq!(got.dist.tc.bitmaps, want.dist.tc.bitmaps, "{ctx}: tc.bitmaps");
    assert_eq!(got.dist.tc.val_ptr, want.dist.tc.val_ptr, "{ctx}: tc.val_ptr");
    assert_eq!(got.dist.tc.values, want.dist.tc.values, "{ctx}: tc.values");
    assert_eq!(got.dist.tc_src_idx, want.dist.tc_src_idx, "{ctx}: tc_src_idx");
    assert_eq!(got.dist.flex_row_ptr, want.dist.flex_row_ptr, "{ctx}: flex_row_ptr");
    assert_eq!(got.dist.flex_cols, want.dist.flex_cols, "{ctx}: flex_cols");
    assert_eq!(got.dist.flex_vals, want.dist.flex_vals, "{ctx}: flex_vals");
    assert_eq!(got.dist.flex_src_idx, want.dist.flex_src_idx, "{ctx}: flex_src_idx");
    assert_eq!(got.dist.stats, want.dist.stats, "{ctx}: stats");
    assert_eq!(got.sched.tc_segments, want.sched.tc_segments, "{ctx}: tc_segments");
    assert_eq!(got.sched.long_tiles, want.sched.long_tiles, "{ctx}: long_tiles");
    assert_eq!(got.sched.short_tiles, want.sched.short_tiles, "{ctx}: short_tiles");
    assert_eq!(got.sched.atomic_windows, want.sched.atomic_windows, "{ctx}: atomic_windows");
}

/// The SDDMM mirror of [`assert_spmm_plans_equal`].
fn assert_sddmm_plans_equal(got: &SddmmPlan, want: &SddmmPlan, ctx: &str) {
    assert_eq!(got.dist.rows, want.dist.rows, "{ctx}: rows");
    assert_eq!(got.dist.cols, want.dist.cols, "{ctx}: cols");
    assert_eq!(got.dist.tc.k, want.dist.tc.k, "{ctx}: tc.k");
    assert_eq!(got.dist.tc.window_of, want.dist.tc.window_of, "{ctx}: tc.window_of");
    assert_eq!(got.dist.tc.cols, want.dist.tc.cols, "{ctx}: tc.cols");
    assert_eq!(got.dist.tc.bitmaps, want.dist.tc.bitmaps, "{ctx}: tc.bitmaps");
    assert_eq!(got.dist.tc.val_ptr, want.dist.tc.val_ptr, "{ctx}: tc.val_ptr");
    assert_eq!(got.dist.tc.values, want.dist.tc.values, "{ctx}: tc.values");
    assert_eq!(got.dist.tc_out_idx, want.dist.tc_out_idx, "{ctx}: tc_out_idx");
    assert_eq!(got.dist.flex_rows, want.dist.flex_rows, "{ctx}: flex_rows");
    assert_eq!(got.dist.flex_cols, want.dist.flex_cols, "{ctx}: flex_cols");
    assert_eq!(got.dist.flex_vals, want.dist.flex_vals, "{ctx}: flex_vals");
    assert_eq!(got.dist.flex_out_idx, want.dist.flex_out_idx, "{ctx}: flex_out_idx");
    assert_eq!(got.dist.stats, want.dist.stats, "{ctx}: stats");
    assert_eq!(got.sched.tc_segments, want.sched.tc_segments, "{ctx}: tc_segments");
    assert_eq!(got.sched.long_tiles, want.sched.long_tiles, "{ctx}: long_tiles");
    assert_eq!(got.sched.short_tiles, want.sched.short_tiles, "{ctx}: short_tiles");
}

#[test]
fn chained_deltas_match_scratch_spmm() {
    check(Config::default().cases(24), "chained spmm deltas == scratch", |rng| {
        let dparams = random_dist_params(rng);
        let bparams = random_balance_params(rng);
        let mut m = testgen::pattern_family(rng, 96);
        let mut plan = preprocess_spmm(&m, &dparams, &bparams, PrepMode::Sequential);
        let mut digests = PatternDigests::of(&m);
        for step in 0..8 {
            let delta = testgen::random_edge_delta(rng, &m, 10);
            let new_m = m.apply_delta(&delta).unwrap();
            let touched = delta.touched_windows();
            plan = plan.apply_delta(&m, &new_m, &touched, &dparams, &bparams);
            digests.update(&new_m, &touched);
            let want = preprocess_spmm(&new_m, &dparams, &bparams, PrepMode::Sequential);
            assert_spmm_plans_equal(&plan, &want, &format!("step {step}"));
            assert_eq!(
                digests.fingerprint(),
                new_m.pattern_fingerprint(),
                "step {step}: incremental fingerprint diverged"
            );
            plan.dist.validate_cover(&new_m).unwrap();
            m = new_m;
        }
        // executed bit-identity of the final patched plan, under both
        // deterministic executor configs
        let b = Dense::random(rng, m.cols, rng.range(1, 12));
        let want_plan = preprocess_spmm(&m, &dparams, &bparams, PrepMode::Sequential);
        for threading in [Threading::Inline, Threading::Scoped] {
            let mut got_x = SpmmExecutor::from_plan(plan.clone(), TcBackend::NativeBitmap);
            let mut want_x = SpmmExecutor::from_plan(want_plan.clone(), TcBackend::NativeBitmap);
            got_x.flex_threads = 1;
            want_x.flex_threads = 1;
            got_x.threading = threading.clone();
            want_x.threading = threading.clone();
            let got = got_x.execute(&b).unwrap();
            let want = want_x.execute(&b).unwrap();
            assert_eq!(got.data, want.data, "executed SpMM output diverged");
        }
    });
}

#[test]
fn chained_deltas_match_scratch_sddmm() {
    check(Config::default().cases(20), "chained sddmm deltas == scratch", |rng| {
        let dparams = random_sddmm_dist_params(rng);
        let bparams = random_balance_params(rng);
        let mut m = testgen::pattern_family(rng, 80);
        let mut plan = preprocess_sddmm(&m, &dparams, &bparams, PrepMode::Sequential);
        let mut digests = PatternDigests::of(&m);
        for step in 0..8 {
            let delta = testgen::random_edge_delta(rng, &m, 10);
            let new_m = m.apply_delta(&delta).unwrap();
            let touched = delta.touched_windows();
            plan = plan.apply_delta(&m, &new_m, &touched, &dparams, &bparams);
            digests.update(&new_m, &touched);
            let want = preprocess_sddmm(&new_m, &dparams, &bparams, PrepMode::Sequential);
            assert_sddmm_plans_equal(&plan, &want, &format!("step {step}"));
            assert_eq!(
                digests.fingerprint(),
                new_m.pattern_fingerprint(),
                "step {step}: incremental fingerprint diverged"
            );
            m = new_m;
        }
        // executed bit-identity: SDDMM writes each nonzero exactly
        // once, so it is deterministic at any flexible width
        let k = rng.range(1, 10);
        let a = Dense::random(rng, m.rows, k);
        let b = Dense::random(rng, m.cols, k);
        let want_plan = preprocess_sddmm(&m, &dparams, &bparams, PrepMode::Sequential);
        let got_x = SddmmExecutor::from_plan(
            plan.clone(),
            std::sync::Arc::new(m.clone()),
            TcBackend::NativeBitmap,
        );
        let want_x = SddmmExecutor::from_plan(
            want_plan,
            std::sync::Arc::new(m.clone()),
            TcBackend::NativeBitmap,
        );
        let got = got_x.execute(&a, &b).unwrap();
        let want = want_x.execute(&a, &b).unwrap();
        assert_eq!(got.values, want.values, "executed SDDMM output diverged");
    });
}

#[test]
fn window_emptying_and_straddling_deltas() {
    let mut rng = SplitMix64::new(42);
    let dparams = DistParams::default();
    let bparams = BalanceParams::default();
    let m = testgen::random_csr(&mut rng, 24, 20, 0.3);
    let plan = preprocess_spmm(&m, &dparams, &bparams, PrepMode::Sequential);

    // d1 empties window 1 entirely (deletes every edge of rows 8..16)
    let mut d1 = EdgeDelta::new();
    for r in 8..16 {
        let (cols, _) = m.row(r);
        for &c in cols {
            d1.delete(r, c as usize);
        }
    }
    assert!(!d1.is_empty(), "fixture needs edges in rows 8..16");
    let m1 = m.apply_delta(&d1).unwrap();
    assert_eq!(m1.row_ptr[8], m1.row_ptr[16], "window 1 should be empty");
    let patched = plan.apply_delta(&m, &m1, &d1.touched_windows(), &dparams, &bparams);
    let scratch = preprocess_spmm(&m1, &dparams, &bparams, PrepMode::Sequential);
    assert_spmm_plans_equal(&patched, &scratch, "emptied window");

    // d2 straddles the window 0 / window 1 boundary
    let mut d2 = EdgeDelta::new();
    d2.upsert(7, 19, 1.25).upsert(8, 0, -2.0);
    assert_eq!(d2.touched_windows(), vec![0, 1]);
    let m2 = m1.apply_delta(&d2).unwrap();
    let patched2 = patched.apply_delta(&m1, &m2, &d2.touched_windows(), &dparams, &bparams);
    let scratch2 = preprocess_spmm(&m2, &dparams, &bparams, PrepMode::Sequential);
    assert_spmm_plans_equal(&patched2, &scratch2, "straddling delta");
}

#[test]
fn fingerprint_pattern_identity_edge_cases() {
    // empty matrices: equal across instances, shape-sensitive
    let e1 = Csr::zeros(10, 10);
    let e2 = Csr::zeros(10, 10);
    assert_eq!(e1.pattern_fingerprint(), e2.pattern_fingerprint());
    assert_ne!(e1.pattern_fingerprint(), Csr::zeros(11, 10).pattern_fingerprint());
    assert_eq!(e1.pattern_fingerprint().nnz, 0);

    // fingerprints identify the *pattern*: value changes are invisible
    let mut rng = SplitMix64::new(7);
    let m = testgen::random_csr(&mut rng, 40, 30, 0.15);
    let mut revalued = m.clone();
    for v in &mut revalued.values {
        *v *= -3.5;
    }
    assert_eq!(m.pattern_fingerprint(), revalued.pattern_fingerprint());
    assert_eq!(PatternDigests::of(&m), PatternDigests::of(&revalued));
}

#[test]
fn delta_to_already_cached_pattern_reuses_entry() {
    use libra::serve::{CachedPlan, PlanCache, PlanKey};
    use std::sync::Arc;

    let cache = PlanCache::new(1 << 22);
    let mut rng = SplitMix64::new(91);
    let dparams = DistParams::default();
    let bparams = BalanceParams::default();
    let a = testgen::random_csr(&mut rng, 48, 40, 0.1);
    // a guaranteed-structural insertion (never a value-only upsert)
    let r = 5;
    let c = (0..a.cols).find(|&c| a.get(r, c).is_none()).unwrap();
    let mut delta = EdgeDelta::new();
    delta.upsert(r, c, 2.5);
    let b = a.apply_delta(&delta).unwrap();

    // serve BOTH patterns first, so the delta's target is already hot
    let fp_a = cache.record_pattern(&a);
    let fp_b = cache.record_pattern(&b);
    let key_a = PlanKey::spmm(fp_a, &dparams, &bparams);
    let key_b = PlanKey::spmm(fp_b, &dparams, &bparams);
    let plan_a = Arc::new(preprocess_spmm(&a, &dparams, &bparams, PrepMode::Sequential));
    let plan_b = Arc::new(preprocess_spmm(&b, &dparams, &bparams, PrepMode::Sequential));
    assert!(cache.insert(key_a, CachedPlan::Spmm(plan_a)));
    assert!(cache.insert(key_b, CachedPlan::Spmm(plan_b.clone())));
    let (len_before, ins_before) = (cache.len(), cache.stats().insertions);

    // the delta lands on the already-cached pattern: the cache must
    // hand back the existing entry, not patch-and-insert a duplicate
    let applied = cache.apply_delta(&key_a, &delta).unwrap();
    assert_eq!(applied.new_key, key_b);
    assert_eq!(applied.new_fp, fp_b);
    assert_eq!(cache.len(), len_before);
    assert_eq!(cache.stats().insertions, ins_before);
    let CachedPlan::Spmm(got) = applied.plan else {
        panic!("expected an SpMM plan");
    };
    assert!(Arc::ptr_eq(&got, &plan_b), "must reuse the resident entry");
}
