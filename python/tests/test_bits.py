"""Unit + property tests for the bitmap decode/compact primitives."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bits, ref


def np_bits(bitmap_int, n):
    return np.array([(bitmap_int >> i) & 1 for i in range(n)], dtype=np.int32)


class TestUnpackBits:
    def test_known_pattern(self):
        words = jnp.array([[0b1011, 0]], dtype=jnp.uint32)
        out = np.asarray(bits.unpack_bits(words, 64))
        assert out[0, 0] == 1 and out[0, 1] == 1 and out[0, 2] == 0 and out[0, 3] == 1
        assert out[0, 4:].sum() == 0

    def test_high_word(self):
        words = jnp.array([[0, 1]], dtype=jnp.uint32)
        out = np.asarray(bits.unpack_bits(words, 64))
        assert out[0, 32] == 1
        assert out.sum() == 1

    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=(1 << 64) - 1))
    def test_matches_python_int(self, bm):
        words = jnp.array([ref.pack_bitmap_words(bm, 2)], dtype=jnp.uint32)
        out = np.asarray(bits.unpack_bits(words, 64))[0]
        np.testing.assert_array_equal(out, np_bits(bm, 64))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=(1 << 128) - 1))
    def test_128_bit(self, bm):
        words = jnp.array([ref.pack_bitmap_words(bm, 4)], dtype=jnp.uint32)
        out = np.asarray(bits.unpack_bits(words, 128))[0]
        np.testing.assert_array_equal(out, np_bits(bm, 128))


class TestDecodeValues:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.booleans(), min_size=64, max_size=64))
    def test_roundtrip_against_dense(self, mask):
        mask = np.array(mask, dtype=np.int32)
        dense = mask * np.arange(1.0, 65.0, dtype=np.float32)
        packed = np.zeros(64, np.float32)
        packed[: mask.sum()] = dense[mask == 1]
        out = np.asarray(
            bits.decode_values(jnp.array(mask[None]), jnp.array(packed[None]))
        )[0]
        np.testing.assert_allclose(out, dense)

    def test_empty(self):
        out = np.asarray(
            bits.decode_values(jnp.zeros((1, 64), jnp.int32), jnp.zeros((1, 64)))
        )
        assert out.sum() == 0


class TestCompactValues:
    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.booleans(), min_size=128, max_size=128))
    def test_compact_then_decode(self, mask):
        mask = np.array(mask, dtype=np.int32)
        dense = np.arange(1.0, 129.0, dtype=np.float32) * mask
        compact = np.asarray(
            bits.compact_values(jnp.array(mask[None]), jnp.array(dense[None]))
        )[0]
        nnz = int(mask.sum())
        # first nnz entries = the set-bit values, ascending bit order
        np.testing.assert_allclose(compact[:nnz], dense[mask == 1])
        np.testing.assert_allclose(compact[nnz:], 0.0)

    def test_compact_is_inverse_of_decode(self):
        rng = np.random.default_rng(7)
        mask = (rng.random(128) < 0.3).astype(np.int32)
        packed = np.zeros(128, np.float32)
        packed[: mask.sum()] = rng.standard_normal(mask.sum()).astype(np.float32)
        dense = np.asarray(bits.decode_values(jnp.array(mask[None]), jnp.array(packed[None])))
        back = np.asarray(bits.compact_values(jnp.array(mask[None]), jnp.array(dense)))
        np.testing.assert_allclose(back, packed[None])
