"""Shared fixtures/helpers for the kernel test suite."""

import numpy as np
import pytest

from compile.kernels import ref


def make_spmm_blocks(rng, g, n, density=0.3):
    """Random SpMM TC-block batch: returns (tiles, bitmap_words, packed, b)."""
    tiles = rng.random((g, 8, 8)).astype(np.float32)
    tiles *= (rng.random((g, 8, 8)) < density).astype(np.float32)
    words = np.zeros((g, 2), np.uint32)
    packed = np.zeros((g, 64), np.float32)
    for i in range(g):
        bm, v = ref.encode_block_np(tiles[i])
        words[i] = ref.pack_bitmap_words(bm, 2)
        packed[i, : len(v)] = v
    b = rng.standard_normal((g, 8, n)).astype(np.float32)
    return tiles, words, packed, b


def make_sddmm_blocks(rng, g, k, density=0.25):
    """Random SDDMM batch: (a_rows, b_cols, sparse_tiles, words, scale)."""
    a_rows = rng.standard_normal((g, 8, k)).astype(np.float32)
    b_cols = rng.standard_normal((g, k, 16)).astype(np.float32)
    stiles = rng.random((g, 8, 16)).astype(np.float32)
    stiles *= (rng.random((g, 8, 16)) < density).astype(np.float32)
    words = np.zeros((g, 4), np.uint32)
    scale = np.zeros((g, 128), np.float32)
    for i in range(g):
        bm, v = ref.encode_block_np(stiles[i])
        words[i] = ref.pack_bitmap_words(bm, 4)
        scale[i, : len(v)] = v
    return a_rows, b_cols, stiles, words, scale


@pytest.fixture
def rng():
    return np.random.default_rng(0xBEEF)
