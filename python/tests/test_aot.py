"""AOT pipeline tests: registry integrity and HLO-text lowering."""

import json
import os
import re
import subprocess
import sys

import jax
import pytest

from compile import aot


def test_registry_names_unique_and_wellformed():
    arts = aot.artifact_registry()
    assert len(arts) >= 40
    for name in arts:
        assert re.fullmatch(r"[a-z0-9_]+", name), name


def test_registry_specs_have_static_shapes():
    arts = aot.artifact_registry()
    for name, (_, specs) in arts.items():
        for s in specs:
            assert all(isinstance(d, int) and d > 0 for d in s.shape), (name, s)


@pytest.mark.parametrize("name", ["spmm_tc_bitmap_256x32", "linear_2048x64x16", "softmax_xent_2048x16"])
def test_lowering_produces_parsable_hlo(name):
    arts = aot.artifact_registry()
    fn, specs = arts[name]
    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # outputs are a tuple (return_tuple=True)
    assert "tuple(" in text.replace(" ", "") or ") tuple" in text


def test_cli_with_filter(tmp_path):
    env = dict(os.environ)
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--only", "relu_bwd_2048x16"],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )
    assert out.returncode == 0, out.stderr
    man = json.loads((tmp_path / "manifest.json").read_text())
    names = [a["name"] for a in man["artifacts"]]
    assert names == ["relu_bwd_2048x16"]
    art = man["artifacts"][0]
    assert art["inputs"][0] == {"shape": [2048, 16], "dtype": "f32"}
    assert (tmp_path / "relu_bwd_2048x16.hlo.txt").exists()
