"""Pallas SDDMM structured kernel vs oracle: in-kernel sampling +
compaction must match per-element dot products."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, sddmm_tc
from .conftest import make_sddmm_blocks


def expected_compacted(a_rows, b_cols, stiles):
    """Per-element oracle: for each set bit (ascending), dot * scale."""
    g = a_rows.shape[0]
    dense = np.einsum("gik,gkn->gin", a_rows, b_cols).reshape(g, 128)
    out = np.zeros((g, 128), np.float32)
    for i in range(g):
        flat = stiles[i].reshape(-1)
        setbits = np.nonzero(flat)[0]
        out[i, : len(setbits)] = dense[i, setbits] * flat[setbits]
    return out


@pytest.mark.parametrize("g,k", [(64, 32), (128, 128)])
def test_bitmap_kernel_matches_oracle(rng, g, k):
    a_rows, b_cols, stiles, words, scale = make_sddmm_blocks(rng, g, k)
    out = np.asarray(
        sddmm_tc.sddmm_tc_bitmap(
            jnp.array(a_rows), jnp.array(b_cols), jnp.array(words), jnp.array(scale), gb=32
        )
    )
    np.testing.assert_allclose(out, expected_compacted(a_rows, b_cols, stiles), rtol=1e-3, atol=1e-3)


def test_bitmap_kernel_matches_ref(rng):
    a_rows, b_cols, _, words, scale = make_sddmm_blocks(rng, 64, 32)
    out = np.asarray(
        sddmm_tc.sddmm_tc_bitmap(
            jnp.array(a_rows), jnp.array(b_cols), jnp.array(words), jnp.array(scale), gb=32
        )
    )
    r = np.asarray(
        ref.sddmm_tc_bitmap_ref(jnp.array(a_rows), jnp.array(b_cols), jnp.array(words), jnp.array(scale))
    )
    np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-5)


def test_dense_variant(rng):
    a_rows, b_cols, _, _, _ = make_sddmm_blocks(rng, 64, 32)
    out = np.asarray(sddmm_tc.sddmm_tc_dense(jnp.array(a_rows), jnp.array(b_cols), gb=32))
    np.testing.assert_allclose(
        out, np.einsum("gik,gkn->gin", a_rows, b_cols), rtol=1e-4, atol=1e-4
    )


def test_empty_bitmap_zero_output(rng):
    g, k = 32, 32
    a_rows = rng.standard_normal((g, 8, k)).astype(np.float32)
    b_cols = rng.standard_normal((g, k, 16)).astype(np.float32)
    words = np.zeros((g, 4), np.uint32)
    scale = np.zeros((g, 128), np.float32)
    out = np.asarray(
        sddmm_tc.sddmm_tc_bitmap(
            jnp.array(a_rows), jnp.array(b_cols), jnp.array(words), jnp.array(scale), gb=32
        )
    )
    assert np.abs(out).max() == 0.0


@settings(max_examples=8, deadline=None)
@given(
    k=st.sampled_from([32, 128]),
    density=st.floats(min_value=0.02, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_density_sweep(k, density, seed):
    rng = np.random.default_rng(seed)
    a_rows, b_cols, stiles, words, scale = make_sddmm_blocks(rng, 64, k, density)
    out = np.asarray(
        sddmm_tc.sddmm_tc_bitmap(
            jnp.array(a_rows), jnp.array(b_cols), jnp.array(words), jnp.array(scale), gb=32
        )
    )
    np.testing.assert_allclose(out, expected_compacted(a_rows, b_cols, stiles), rtol=1e-3, atol=2e-3)
