"""Pallas SpMM structured kernel vs pure-jnp oracle — the core L1
correctness signal (bitmap decode + block matmul)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref, spmm_tc
from .conftest import make_spmm_blocks


@pytest.mark.parametrize("g,n,gb", [(64, 32, 32), (128, 128, 64), (256, 32, 64)])
def test_bitmap_kernel_matches_dense_einsum(rng, g, n, gb):
    tiles, words, packed, b = make_spmm_blocks(rng, g, n)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(jnp.array(words), jnp.array(packed), jnp.array(b), gb=gb)
    )
    expect = np.einsum("gik,gkn->gin", tiles, b)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("g,n", [(64, 32), (128, 128)])
def test_bitmap_kernel_matches_ref(rng, g, n):
    _, words, packed, b = make_spmm_blocks(rng, g, n)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(jnp.array(words), jnp.array(packed), jnp.array(b), gb=32)
    )
    r = np.asarray(ref.spmm_tc_bitmap_ref(jnp.array(words), jnp.array(packed), jnp.array(b)))
    np.testing.assert_allclose(out, r, rtol=1e-5, atol=1e-5)


def test_dense_variant_matches(rng):
    tiles, _, _, b = make_spmm_blocks(rng, 128, 32)
    out = np.asarray(spmm_tc.spmm_tc_dense(jnp.array(tiles), jnp.array(b), gb=64))
    expect = np.einsum("gik,gkn->gin", tiles, b)
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-4)


def test_empty_blocks_produce_zero(rng):
    g, n = 64, 32
    words = np.zeros((g, 2), np.uint32)
    packed = np.zeros((g, 64), np.float32)
    b = rng.standard_normal((g, 8, n)).astype(np.float32)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(jnp.array(words), jnp.array(packed), jnp.array(b), gb=32)
    )
    assert np.abs(out).max() == 0.0


def test_full_blocks(rng):
    """All 64 bits set: decode must reproduce the full dense tile."""
    g, n = 32, 32
    tiles = rng.standard_normal((g, 8, 8)).astype(np.float32)
    tiles[tiles == 0.0] = 1.0
    words = np.zeros((g, 2), np.uint32)
    packed = np.zeros((g, 64), np.float32)
    for i in range(g):
        bm, v = ref.encode_block_np(tiles[i])
        assert bm == (1 << 64) - 1
        words[i] = ref.pack_bitmap_words(bm, 2)
        packed[i, : len(v)] = v
    b = rng.standard_normal((g, 8, n)).astype(np.float32)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(jnp.array(words), jnp.array(packed), jnp.array(b), gb=32)
    )
    np.testing.assert_allclose(out, np.einsum("gik,gkn->gin", tiles, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    g_exp=st.integers(min_value=5, max_value=8),
    n=st.sampled_from([32, 128]),
    density=st.floats(min_value=0.02, max_value=0.9),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_hypothesis_shape_density_sweep(g_exp, n, density, seed):
    rng = np.random.default_rng(seed)
    g = 2**g_exp
    tiles, words, packed, b = make_spmm_blocks(rng, g, n, density)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(jnp.array(words), jnp.array(packed), jnp.array(b), gb=32)
    )
    expect = np.einsum("gik,gkn->gin", tiles, b)
    np.testing.assert_allclose(out, expect, rtol=1e-3, atol=1e-3)


def test_bf16_variant_runs(rng):
    """bf16 inputs: looser tolerance, checks the precision path lowers."""
    tiles, words, packed, b = make_spmm_blocks(rng, 64, 32)
    out = np.asarray(
        spmm_tc.spmm_tc_bitmap(
            jnp.array(words),
            jnp.array(packed).astype(jnp.bfloat16),
            jnp.array(b).astype(jnp.bfloat16),
            gb=32,
        ).astype(jnp.float32)
    )
    expect = np.einsum("gik,gkn->gin", tiles, b)
    np.testing.assert_allclose(out, expect, rtol=0.1, atol=0.1)
