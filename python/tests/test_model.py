"""L2 GNN dense tile tests: forward/backward math + padding behavior."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


class TestLinear:
    def test_fwd(self):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((16, 8)).astype(np.float32)
        w = rng.standard_normal((8, 4)).astype(np.float32)
        (y,) = model.linear_fwd(jnp.array(x), jnp.array(w))
        np.testing.assert_allclose(np.asarray(y), x @ w, rtol=1e-5, atol=1e-5)

    def test_relu_fused(self):
        rng = np.random.default_rng(2)
        x = rng.standard_normal((16, 8)).astype(np.float32)
        w = rng.standard_normal((8, 4)).astype(np.float32)
        (y,) = model.linear_relu_fwd(jnp.array(x), jnp.array(w))
        np.testing.assert_allclose(np.asarray(y), np.maximum(x @ w, 0), rtol=1e-5, atol=1e-5)

    def test_grads_match_autodiff(self):
        rng = np.random.default_rng(3)
        x = jnp.array(rng.standard_normal((8, 5)).astype(np.float32))
        w = jnp.array(rng.standard_normal((5, 3)).astype(np.float32))
        dy = jnp.array(rng.standard_normal((8, 3)).astype(np.float32))

        def f(x, w):
            return jnp.sum(model.linear_fwd(x, w)[0] * dy)

        gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
        (dw,) = model.grad_w(x, dy)
        (dx,) = model.grad_x(dy, w)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(gw), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(gx), rtol=1e-5, atol=1e-5)

    def test_zero_padding_rows_are_neutral(self):
        rng = np.random.default_rng(4)
        x = rng.standard_normal((8, 5)).astype(np.float32)
        w = rng.standard_normal((5, 3)).astype(np.float32)
        xp = np.vstack([x, np.zeros((4, 5), np.float32)])
        (y,) = model.linear_fwd(jnp.array(xp), jnp.array(w))
        np.testing.assert_allclose(np.asarray(y)[:8], x @ w, rtol=1e-5, atol=1e-5)
        assert np.abs(np.asarray(y)[8:]).max() == 0.0
        # grad_w ignores zero rows entirely
        dy = np.vstack(
            [rng.standard_normal((8, 3)).astype(np.float32), np.zeros((4, 3), np.float32)]
        )
        (dw,) = model.grad_w(jnp.array(xp), jnp.array(dy))
        np.testing.assert_allclose(np.asarray(dw), x.T @ dy[:8], rtol=1e-5, atol=1e-5)


class TestSoftmaxXent:
    def test_loss_and_grad_match_autodiff(self):
        rng = np.random.default_rng(5)
        logits = jnp.array(rng.standard_normal((6, 4)).astype(np.float32))
        labels = rng.integers(0, 4, 6)
        onehot = jnp.array(np.eye(4, dtype=np.float32)[labels])

        def f(z):
            zmax = jnp.max(z, axis=1, keepdims=True)
            logp = z - zmax - jnp.log(jnp.sum(jnp.exp(z - zmax), axis=1, keepdims=True))
            return -jnp.mean(jnp.sum(logp * onehot, axis=1))

        loss, dlogits = model.softmax_xent(logits, onehot)
        np.testing.assert_allclose(float(loss[0]), float(f(logits)), rtol=1e-5)
        g = jax.grad(f)(logits)
        np.testing.assert_allclose(np.asarray(dlogits), np.asarray(g), rtol=1e-4, atol=1e-5)

    def test_padding_rows_excluded(self):
        rng = np.random.default_rng(6)
        logits = rng.standard_normal((4, 3)).astype(np.float32)
        onehot = np.zeros((4, 3), np.float32)
        onehot[0, 1] = 1.0
        onehot[1, 2] = 1.0  # rows 2,3 are padding
        loss, dlogits = model.softmax_xent(jnp.array(logits), jnp.array(onehot))
        loss2, _ = model.softmax_xent(jnp.array(logits[:2]), jnp.array(onehot[:2]))
        np.testing.assert_allclose(float(loss[0]), float(loss2[0]), rtol=1e-5)
        assert np.abs(np.asarray(dlogits)[2:]).max() == 0.0

    def test_relu_bwd(self):
        y = jnp.array([[0.0, 2.0], [3.0, 0.0]])
        dy = jnp.array([[1.0, 1.0], [1.0, 1.0]])
        (dx,) = model.relu_bwd(y, dy)
        np.testing.assert_allclose(np.asarray(dx), [[0, 1], [1, 0]])
