"""Layer-1 Pallas kernels for Libra's structured (TC-block) engine.

All kernels are authored for the MXU mental model (8xK tiles, batched
MMA) but lowered with ``interpret=True`` so the resulting HLO runs on
the CPU PJRT client that the Rust coordinator embeds. See
DESIGN.md "Hardware adaptation".
"""

from . import ref  # noqa: F401
from .spmm_tc import spmm_tc_bitmap, spmm_tc_dense  # noqa: F401
from .sddmm_tc import sddmm_tc_bitmap, sddmm_tc_dense  # noqa: F401
