"""Shared bitmap helpers (the vectorized form of Bit-Decoding).

A TC block's nonzero layout is a row-major bitmap (bit ``r*k + c``);
its values are stored compressed in ascending bit order. On the GPU the
paper decodes with per-thread ``__popc`` prefix masks; the vectorized
TPU/XLA equivalent is an exclusive cumulative sum over the bit vector:

    prefix[i] = popcount(bitmap & ((1 << i) - 1)) = cumsum(bits)[i] - bits[i]

which every lane computes in parallel, followed by a gather from the
compressed value array.
"""

import jax.numpy as jnp


def unpack_bits(words, n_bits):
    """Unpack uint32 words [..., W] into bits [..., n_bits] (int32).

    Bit ``i`` of the block bitmap lives in word ``i // 32``, bit
    ``i % 32`` — matching the Rust packer in ``runtime/pack.rs``.
    """
    w = words.shape[-1]
    assert w * 32 >= n_bits, (w, n_bits)
    positions = jnp.arange(32, dtype=jnp.uint32)
    # [..., W, 32] -> [..., W*32]
    bits = (words[..., :, None] >> positions) & jnp.uint32(1)
    bits = bits.reshape(*words.shape[:-1], w * 32)
    return bits[..., :n_bits].astype(jnp.int32)


def decode_values(bits, packed_values):
    """Expand compressed values into dense bit-position order.

    bits: [..., B] 0/1 int32; packed_values: [..., B] where the first
    ``sum(bits)`` entries are the nonzero values in ascending bit order.
    Returns dense [..., B]: value at set bits, 0 elsewhere.
    """
    prefix = jnp.cumsum(bits, axis=-1) - bits  # exclusive prefix popcount
    gathered = jnp.take_along_axis(packed_values, prefix, axis=-1)
    return gathered * bits.astype(packed_values.dtype)


def compact_values(bits, dense):
    """Inverse of :func:`decode_values`: gather dense bit-position values
    into compressed ascending-bit order (the in-kernel SDDMM sampling).

    Returns [..., B] with the set-bit values first (bit-ascending) and
    zeros after. Uses the argsort trick: set bits keep their position as
    the sort key, unset bits are pushed past the end.
    """
    n = bits.shape[-1]
    idx = jnp.arange(n, dtype=jnp.int32)
    keys = jnp.where(bits == 1, idx, n + idx)
    order = jnp.argsort(keys, axis=-1)
    compacted = jnp.take_along_axis(dense * bits.astype(dense.dtype), order, axis=-1)
    return compacted
