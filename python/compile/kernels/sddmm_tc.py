"""SDDMM structured-engine Pallas kernels.

``dense[g] = a_rows[g] @ b_cols[g]`` followed by in-kernel sampling:
only the positions set in the block bitmap are kept, compacted into
bit-ascending order and scaled by the sparse matrix's own values.

The compaction is the kernel-level analog of the paper's Bit-Decoding
write-back: each output element's destination is known from the bitmap
alone (prefix popcount = exclusive cumsum), so no traversal of the
preceding nonzeros is needed — unlike the TC-GNN-style dense variant
(:func:`sddmm_tc_dense`) where the host walks the block to sample.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bits

DEFAULT_GB = 32


def _bitmap_kernel(a_ref, b_ref, bm_ref, scale_ref, o_ref):
    a = a_ref[...]  # [GB, 8, K]
    b = b_ref[...]  # [GB, K, 16]
    bm = bm_ref[...]  # [GB, 4] uint32
    scale = scale_ref[...]  # [GB, 128]
    dense = jnp.einsum("gik,gkn->gin", a, b, preferred_element_type=jnp.float32)
    dense = dense.reshape(dense.shape[0], 128)
    bvec = bits.unpack_bits(bm, 128)
    o_ref[...] = (bits.compact_values(bvec, dense) * scale).astype(o_ref.dtype)


def _dense_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.einsum(
        "gik,gkn->gin", a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gb",))
def sddmm_tc_bitmap(a_rows, b_cols, bitmap_words, scale_values, gb=DEFAULT_GB):
    """Libra bitmap SDDMM kernel over a [G] batch of 8x16 TC blocks.

    Shapes: a_rows [G, 8, K]; b_cols [G, K, 16]; bitmap_words [G, 4]
    u32; scale_values [G, 128] -> [G, 128] compacted sampled values.
    """
    g, _, k = a_rows.shape
    assert g % gb == 0, (g, gb)
    grid = (g // gb,)
    return pl.pallas_call(
        _bitmap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, 8, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, k, 16), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, 4), lambda i: (i, 0)),
            pl.BlockSpec((gb, 128), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((gb, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 128), a_rows.dtype),
        interpret=True,
    )(a_rows, b_cols, bitmap_words, scale_values)


@functools.partial(jax.jit, static_argnames=("gb",))
def sddmm_tc_dense(a_rows, b_cols, gb=DEFAULT_GB):
    """Dense-output SDDMM (TC-GNN-style): the host samples afterwards."""
    g, _, k = a_rows.shape
    assert g % gb == 0, (g, gb)
    grid = (g // gb,)
    return pl.pallas_call(
        _dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, 8, k), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, k, 16), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((gb, 8, 16), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 8, 16), a_rows.dtype),
        interpret=True,
    )(a_rows, b_cols)
