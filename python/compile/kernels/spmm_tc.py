"""SpMM structured-engine Pallas kernels.

The hot spot of Libra's structured path: a batch of G bitmap-compressed
8x8 TC blocks multiplied against their gathered dense operands,
``out[g] = decode(bitmap[g], values[g]) @ b_gathered[g]``.

MXU adaptation (DESIGN.md "Hardware adaptation"): the GPU paper issues
one ``mma.m16n8k8`` per TC block from a warp, with Bit-Decoding done by
per-thread ``__popc`` on a register-held bitmap. On the TPU model we
batch ``GB`` blocks per grid step so the (8, K)x(K, N) tiles fill the
MXU lanes, and Bit-Decoding becomes an exclusive cumsum + gather on the
VPU, fused ahead of the matmul in the same kernel — the compressed
values never round-trip through a staging buffer (the shared-memory
bypass property).

Two variants:
 * :func:`spmm_tc_bitmap` — bitmap + compressed values in, decode
   in-kernel (Libra's Bit-Decoding).
 * :func:`spmm_tc_dense`  — pre-decoded dense tiles in (the ME-TCF /
   staged baseline for the Table-8 ablation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bits

# Blocks per grid step: 8 rows x GB blocks fills MXU/VPU lanes while the
# VMEM footprint stays small (see DESIGN.md §Perf for the budget).
DEFAULT_GB = 64


def _bitmap_kernel(bitmap_ref, vals_ref, b_ref, o_ref):
    """One grid step: decode GB blocks and contract with their B tiles."""
    bm = bitmap_ref[...]  # [GB, 2] uint32
    vals = vals_ref[...]  # [GB, 64]
    b = b_ref[...]  # [GB, 8, N]
    bvec = bits.unpack_bits(bm, 64)  # [GB, 64] int32
    dense = bits.decode_values(bvec, vals)  # [GB, 64]
    a = dense.reshape(dense.shape[0], 8, 8)
    o_ref[...] = jnp.einsum(
        "gik,gkn->gin", a, b, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _dense_kernel(a_ref, b_ref, o_ref):
    """Staged variant: tiles arrive pre-decoded."""
    o_ref[...] = jnp.einsum(
        "gik,gkn->gin", a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("gb",))
def spmm_tc_bitmap(bitmap_words, packed_values, b_gathered, gb=DEFAULT_GB):
    """Libra bitmap SpMM kernel over a [G] batch of TC blocks.

    Shapes: bitmap_words [G, 2] u32; packed_values [G, 64] f32;
    b_gathered [G, 8, N] f32 -> [G, 8, N] f32. G must be a multiple of
    ``gb`` (the Rust packer pads with empty blocks).
    """
    g, _, n = b_gathered.shape
    assert g % gb == 0, (g, gb)
    grid = (g // gb,)
    return pl.pallas_call(
        _bitmap_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, 2), lambda i: (i, 0)),
            pl.BlockSpec((gb, 64), lambda i: (i, 0)),
            pl.BlockSpec((gb, 8, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((gb, 8, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 8, n), b_gathered.dtype),
        interpret=True,  # CPU-PJRT execution; Mosaic lowering is TPU-only
    )(bitmap_words, packed_values, b_gathered)


@functools.partial(jax.jit, static_argnames=("gb",))
def spmm_tc_dense(a_tiles, b_gathered, gb=DEFAULT_GB):
    """Staged (pre-decoded) SpMM kernel — ablation baseline."""
    g, _, n = b_gathered.shape
    assert g % gb == 0, (g, gb)
    grid = (g // gb,)
    return pl.pallas_call(
        _dense_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((gb, 8, 8), lambda i: (i, 0, 0)),
            pl.BlockSpec((gb, 8, n), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((gb, 8, n), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 8, n), b_gathered.dtype),
        interpret=True,
    )(a_tiles, b_gathered)
