"""Pure-jnp reference oracles for the structured-engine kernels.

These define the semantics the Pallas kernels must match bit-for-bit in
structure (and to fp tolerance in value). The Rust test-suite checks
its host-side Bit-Decoding against the same conventions.
"""

import jax.numpy as jnp
import numpy as np

from . import bits


def spmm_tc_bitmap_ref(bitmap_words, packed_values, b_gathered):
    """Reference for the bitmap SpMM TC kernel.

    bitmap_words: [G, 2] uint32 (64-bit row-major 8x8 bitmap per block)
    packed_values: [G, 64] f32 (compressed values, bit-ascending)
    b_gathered: [G, 8, N] f32 (rows of B for the block's 8 column slots;
                zero rows for padding slots)
    Returns [G, 8, N]: the per-block partial products A_block @ B_block.
    """
    g = bitmap_words.shape[0]
    bvec = bits.unpack_bits(bitmap_words, 64)  # [G, 64]
    dense = bits.decode_values(bvec, packed_values)  # [G, 64]
    a = dense.reshape(g, 8, 8)
    return jnp.einsum("gik,gkn->gin", a, b_gathered, preferred_element_type=jnp.float32)


def spmm_tc_dense_ref(a_tiles, b_gathered):
    """Reference for the staged (pre-decoded) SpMM variant."""
    return jnp.einsum(
        "gik,gkn->gin", a_tiles, b_gathered, preferred_element_type=jnp.float32
    )


def sddmm_tc_bitmap_ref(a_rows, b_cols, bitmap_words, scale_values):
    """Reference for the bitmap SDDMM TC kernel.

    a_rows: [G, 8, K] f32 (window rows of A per block)
    b_cols: [G, K, 16] f32 (columns of B for the block's 16 slots)
    bitmap_words: [G, 4] uint32 (128-bit row-major 8x16 bitmap)
    scale_values: [G, 128] f32 (the sparse matrix's own values,
                  compressed bit-ascending — SDDMM scales the sampled
                  dot products by them)
    Returns [G, 128] f32: compacted sampled results, bit-ascending, with
    zeros after the block's nnz (in-kernel sampling + compaction).
    """
    g = a_rows.shape[0]
    dense = jnp.einsum(
        "gik,gkn->gin", a_rows, b_cols, preferred_element_type=jnp.float32
    ).reshape(g, 128)
    bvec = bits.unpack_bits(bitmap_words, 128)  # [G, 128]
    compacted = bits.compact_values(bvec, dense)
    return compacted * scale_values


def sddmm_tc_dense_ref(a_rows, b_cols):
    """Reference for the dense-output SDDMM variant (host samples)."""
    return jnp.einsum("gik,gkn->gin", a_rows, b_cols, preferred_element_type=jnp.float32)


def linear_ref(x, w):
    """Reference for the GNN dense layer tile."""
    return x @ w


# ---------------------------------------------------------------------------
# numpy host-side helpers shared by the python tests (mirror the Rust packer)
# ---------------------------------------------------------------------------

def pack_bitmap_words(bitmap_int, n_words):
    """Split an arbitrary-precision python int bitmap into uint32 words."""
    return np.array(
        [(bitmap_int >> (32 * w)) & 0xFFFFFFFF for w in range(n_words)], dtype=np.uint32
    )


def encode_block_np(tile):
    """Encode a dense row-major tile (2D numpy) into (bitmap_int, values)."""
    flat = tile.reshape(-1)
    bitmap = 0
    values = []
    for i, v in enumerate(flat):
        if v != 0.0:
            bitmap |= 1 << i
            values.append(v)
    return bitmap, np.array(values, dtype=np.float32)
