"""Layer-2 JAX compute graphs: the GNN dense tiles.

The Rust coordinator runs GCN / AGNN training with *manual* backward
passes; every dense contraction in those passes is one of the tiled
computations below, AOT-lowered per (T, K, N) bucket by ``aot.py``.
The sparse aggregation / attention steps go through the Libra hybrid
executor instead (structured kernels from ``kernels/`` + the native
flexible engine).

Tiling: node dimension is processed in row tiles of T (default 2048);
the Rust side pads the last tile with zero rows, which is harmless for
every op here (matmul, bias, relu — all row-local).
"""

import jax.numpy as jnp


def linear_fwd(x, w):
    """Y = X @ W for one row tile. x: [T, K], w: [K, N] -> [T, N]."""
    return (jnp.matmul(x, w, preferred_element_type=jnp.float32),)


def linear_relu_fwd(x, w):
    """Fused Y = relu(X @ W) — saves one artifact round-trip per layer."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    return (jnp.maximum(y, 0.0),)


def grad_w(x, dy):
    """dW = X^T @ dY. x: [T, K], dy: [T, N] -> [K, N].

    The Rust trainer accumulates tile contributions: dW = sum_t dW_t.
    """
    return (jnp.matmul(x.T, dy, preferred_element_type=jnp.float32),)


def grad_x(dy, w):
    """dX = dY @ W^T. dy: [T, N], w: [K, N] -> [T, K]."""
    return (jnp.matmul(dy, w.T, preferred_element_type=jnp.float32),)


def relu_bwd(y, dy):
    """dX for relu given the *output* y (y > 0 ⇔ input > 0)."""
    return (jnp.where(y > 0.0, dy, 0.0),)


def softmax_xent(logits, onehot):
    """Row softmax cross-entropy: returns (mean loss [1], dlogits [T, C]).

    Rows whose one-hot target is all zero (padding rows) contribute
    neither to the loss nor to the gradient.
    """
    zmax = jnp.max(logits, axis=1, keepdims=True)
    z = logits - zmax
    logsum = jnp.log(jnp.sum(jnp.exp(z), axis=1, keepdims=True))
    logp = z - logsum
    valid = jnp.sum(onehot, axis=1, keepdims=True)  # 1 for real rows, 0 pad
    n = jnp.maximum(jnp.sum(valid), 1.0)
    loss = -jnp.sum(logp * onehot) / n
    dlogits = (jnp.exp(logp) - onehot) * valid / n
    return (jnp.reshape(loss, (1,)), dlogits)
