"""AOT pipeline: lower every kernel/model bucket to HLO text artifacts.

Interchange format is HLO *text* (NOT serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the Rust ``xla`` crate binds) rejects; the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--only REGEX]
Outputs ``<name>.hlo.txt`` per artifact plus ``manifest.json``
describing input/output shapes and dtypes for the Rust loader.
"""

import argparse
import json
import os
import re

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import sddmm_tc, spmm_tc

# Batch-size buckets for the structured sparse kernels. The Rust
# batcher picks the largest bucket <= remaining work and pads the tail.
SPMM_G_BUCKETS = (256, 1024, 4096)
SPMM_N_BUCKETS = (32, 128)
SDDMM_G_BUCKETS = (256, 1024)
SDDMM_K_BUCKETS = (32, 128)

# Dense GNN tile buckets: (K, N) pairs used by the GCN/AGNN configs.
LINEAR_TILE_T = 2048
LINEAR_KN = ((128, 64), (64, 64), (64, 16), (128, 32), (32, 32), (32, 16), (64, 32))
XENT_C = (16,)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _dt_name(dtype) -> str:
    return {"float32": "f32", "uint32": "u32", "bfloat16": "bf16"}[jnp.dtype(dtype).name]


def _gb_for(g, cap=None):
    """Pallas per-step block count for the CPU artifacts.

    On CPU-PJRT the interpret-mode grid loop lowers to an XLA while
    loop whose per-step overhead dwarfs the work (measured 20x slower
    at gb=64 vs gb=G for G=4096), so the CPU artifacts use a single
    grid step. On a real TPU target `cap` would bound the VMEM-resident
    tile instead (see DESIGN.md §Perf for the budget).
    """
    return g if cap is None else min(g, cap)


def artifact_registry():
    """name -> (fn, [input ShapeDtypeStructs]). fn must return a tuple."""
    arts = {}

    # --- SpMM structured kernels -----------------------------------------
    for g in SPMM_G_BUCKETS:
        for n in SPMM_N_BUCKETS:
            for dt, suffix in ((jnp.float32, ""), (jnp.bfloat16, "_bf16")):
                if suffix and g != 1024:
                    continue  # bf16 study uses the mid bucket only
                name = f"spmm_tc_bitmap_{g}x{n}{suffix}"
                gb = _gb_for(g)

                def fn(bm, vals, b, gb=gb):
                    return (spmm_tc.spmm_tc_bitmap(bm, vals, b, gb=gb),)

                arts[name] = (
                    fn,
                    [
                        _spec((g, 2), jnp.uint32),
                        _spec((g, 64), dt),
                        _spec((g, 8, n), dt),
                    ],
                )
    for n in SPMM_N_BUCKETS:
        g = 1024
        name = f"spmm_tc_dense_{g}x{n}"

        def fn_dense(a, b):
            return (spmm_tc.spmm_tc_dense(a, b, gb=64),)

        arts[name] = (fn_dense, [_spec((g, 8, 8), jnp.float32), _spec((g, 8, n), jnp.float32)])

    # --- SDDMM structured kernels ----------------------------------------
    for g in SDDMM_G_BUCKETS:
        for k in SDDMM_K_BUCKETS:
            name = f"sddmm_tc_bitmap_{g}x{k}"
            gb = _gb_for(g)

            def fn_sd(a, b, bm, sv, gb=gb):
                return (sddmm_tc.sddmm_tc_bitmap(a, b, bm, sv, gb=gb),)

            arts[name] = (
                fn_sd,
                [
                    _spec((g, 8, k), jnp.float32),
                    _spec((g, k, 16), jnp.float32),
                    _spec((g, 4), jnp.uint32),
                    _spec((g, 128), jnp.float32),
                ],
            )
    g, k = 1024, 32
    name = f"sddmm_tc_dense_{g}x{k}"

    def fn_sdd(a, b):
        return (sddmm_tc.sddmm_tc_dense(a, b, gb=1024),)

    arts[name] = (fn_sdd, [_spec((g, 8, k), jnp.float32), _spec((g, k, 16), jnp.float32)])

    # --- GNN dense tiles ---------------------------------------------------
    t = LINEAR_TILE_T
    for kk, nn in LINEAR_KN:
        arts[f"linear_{t}x{kk}x{nn}"] = (
            model.linear_fwd,
            [_spec((t, kk), jnp.float32), _spec((kk, nn), jnp.float32)],
        )
        arts[f"linear_relu_{t}x{kk}x{nn}"] = (
            model.linear_relu_fwd,
            [_spec((t, kk), jnp.float32), _spec((kk, nn), jnp.float32)],
        )
        arts[f"grad_w_{t}x{kk}x{nn}"] = (
            model.grad_w,
            [_spec((t, kk), jnp.float32), _spec((t, nn), jnp.float32)],
        )
        arts[f"grad_x_{t}x{kk}x{nn}"] = (
            model.grad_x,
            [_spec((t, nn), jnp.float32), _spec((kk, nn), jnp.float32)],
        )
    for c in XENT_C:
        arts[f"softmax_xent_{t}x{c}"] = (
            model.softmax_xent,
            [_spec((t, c), jnp.float32), _spec((t, c), jnp.float32)],
        )
    for nn in (16, 32, 64):
        arts[f"relu_bwd_{t}x{nn}"] = (
            model.relu_bwd,
            [_spec((t, nn), jnp.float32), _spec((t, nn), jnp.float32)],
        )

    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = artifact_registry()
    manifest = {"artifacts": []}
    pat = re.compile(args.only) if args.only else None
    for name, (fn, in_specs) in sorted(arts.items()):
        if pat and not pat.search(name):
            continue
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.tree_util.tree_leaves(lowered.out_info)
        manifest["artifacts"].append(
            {
                "name": name,
                "inputs": [
                    {"shape": list(s.shape), "dtype": _dt_name(s.dtype)} for s in in_specs
                ],
                "outputs": [
                    {"shape": list(o.shape), "dtype": _dt_name(o.dtype)} for o in outs
                ],
            }
        )
        print(f"wrote {path} ({len(text)} chars)")
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
